//! Strong rules (Tibshirani et al. 2012; paper Sec. 3.6, Eq. 23-24):
//! heuristic sequential screening based on a unit non-expansiveness
//! assumption on the gradient of the data-fitting term. Un-safe: the solver
//! must check KKT conditions at convergence and reactivate violators.
//!
//! Our `Strong` rule composes the strong sequential discard with the
//! (safe) dynamic Gap Safe sphere along the iterations, mirroring how the
//! paper's "strong warm start" experiments are run.
//!
//! The discard test (Eq. 23-24) keeps group `g` iff
//! `Omega_g^D([X^T theta_{t-1}]_g) >= (2 lambda_t - lambda_{t-1}) / lambda_{t-1}`
//! — a unit-slope extrapolation of the correlation, not a certificate,
//! hence the mandatory KKT re-check at convergence
//! ([`super::ScreeningRule::needs_kkt_check`]).

use super::{apply_sphere, PrevSolution, ScreeningRule};
use crate::obs::{self, ledger, Event};
use crate::penalty::ActiveSet;
use crate::problem::{GapResult, Problem};

/// Strong sequential rule + dynamic Gap Safe + KKT post-checking.
pub struct StrongRule {
    pub screened_groups: usize,
    pub kkt_violations: usize,
}

impl StrongRule {
    pub fn new() -> Self {
        StrongRule { screened_groups: 0, kkt_violations: 0 }
    }

    /// The strong active set S_{theta_{t-1}, lambda_t} (Eq. 24) as a mask.
    pub fn strong_active_set(
        prob: &Problem,
        prev: &PrevSolution,
        lam: f64,
    ) -> ActiveSet {
        let full = ActiveSet::full(prob.pen.groups());
        let stats = prob.stats_for_center(&prev.theta, &full);
        let thresh = (2.0 * lam - prev.lam) / prev.lam;
        let mut active = ActiveSet::full(prob.pen.groups());
        for g in 0..prob.n_groups() {
            if stats.group_dual[g] < thresh {
                active.kill_group(prob.pen.groups(), g);
            }
        }
        active
    }
}

impl Default for StrongRule {
    fn default() -> Self {
        Self::new()
    }
}

impl ScreeningRule for StrongRule {
    fn name(&self) -> &'static str {
        "strong"
    }

    fn begin_lambda(
        &mut self,
        prob: &Problem,
        lam: f64,
        _lam_max: f64,
        prev: Option<&PrevSolution>,
        active: &mut ActiveSet,
    ) {
        let Some(prev) = prev else { return };
        // When the grid is sparsely sampled (2 lambda <= lambda_0) the
        // threshold is <= 0 and the rule discards nothing (Sec. 5.1).
        if 2.0 * lam <= prev.lam {
            return;
        }
        let strong = Self::strong_active_set(prob, prev, lam);
        let before_mask = active.group.clone();
        let before = active.n_active_groups();
        active.intersect(&strong);
        let killed = before - active.n_active_groups();
        self.screened_groups += killed;
        // Provenance: the strong discard is a heuristic, not a certificate,
        // so its records carry `test: "strong"` and a NaN radius — the
        // offline verifier re-checks them for *faithfulness* (the recorded
        // correlation really is below the strong threshold), not safety.
        let kf: usize = (0..prob.n_groups())
            .filter(|&g| before_mask[g] && !active.group[g])
            .map(|g| prob.pen.groups().feats(g).len())
            .sum();
        ledger::count_screened("strong", kf);
        if killed > 0 && obs::enabled() && ledger::emit_enabled() {
            let full = ActiveSet::full(prob.pen.groups());
            let stats = prob.stats_for_center(&prev.theta, &full);
            let thresh = (2.0 * lam - prev.lam) / prev.lam;
            let (sid, _, epoch) = ledger::current();
            let cid = ledger::next_id();
            obs::emit(&Event::SphereCenter {
                sid,
                cid,
                lam,
                epoch,
                rule: "strong",
                site: "strong",
                radius: f64::NAN,
                n: prev.theta.rows(),
                q: prev.theta.cols(),
                theta: prev.theta.as_slice().to_vec(),
            });
            for g in 0..prob.n_groups() {
                if !(before_mask[g] && !active.group[g]) {
                    continue;
                }
                for &j in prob.pen.groups().feats(g) {
                    obs::emit(&Event::ScreenCol {
                        sid,
                        cid,
                        lam,
                        epoch,
                        rule: "strong",
                        test: "strong",
                        j,
                        group: g,
                        stat: stats.group_dual[g],
                        norm: prob.norms.op[g],
                        radius: f64::NAN,
                        thresh,
                        margin: thresh - stats.group_dual[g],
                    });
                }
            }
        }
    }

    fn on_gap_pass(
        &mut self,
        prob: &Problem,
        _lam: f64,
        gap: &GapResult,
        active: &mut ActiveSet,
    ) {
        // Safe dynamic screening on top (cheap, and guarantees convergence
        // of the active set even when the strong guess was too aggressive).
        let (kg, _) =
            apply_sphere(prob, &gap.stats, gap.radius, &gap.theta, self.name(), "dyn", active);
        self.screened_groups += kg;
    }

    fn needs_kkt_check(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datafit::Quadratic;
    use crate::linalg::sparse::Design;
    use crate::linalg::Mat;
    use crate::penalty::L1;
    use crate::util::prng::Prng;

    fn toy(seed: u64, n: usize, p: usize) -> Problem {
        let mut rng = Prng::new(seed);
        let mut x = Mat::zeros(n, p);
        for v in x.as_mut_slice() {
            *v = rng.gaussian();
        }
        let y: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        Problem::new(Design::Dense(x), Box::new(Quadratic::from_vec(&y)), Box::new(L1::new(p)))
    }

    fn prev_at_lmax(prob: &Problem) -> PrevSolution {
        let lmax = prob.lambda_max();
        let beta = Mat::zeros(prob.p(), 1);
        let z = prob.predict(&beta);
        let full = ActiveSet::full(prob.pen.groups());
        let g = prob.gap_pass(&beta, &z, lmax, &full);
        PrevSolution {
            lam: lmax,
            beta,
            z: z.clone(),
            theta: g.theta,
            loss: prob.fit.loss(&z),
            pen_value: 0.0,
            active: full,
        }
    }

    #[test]
    fn strong_discards_aggressively() {
        let prob = toy(1, 15, 60);
        let prev = prev_at_lmax(&prob);
        let lam = 0.9 * prev.lam;
        let strong = StrongRule::strong_active_set(&prob, &prev, lam);
        // Strong threshold (2*0.9-1) = 0.8 kills anything with correlation
        // below 0.8 * lam_max: expect most of the iid design gone.
        assert!(strong.n_active_feats() < 30, "{}", strong.n_active_feats());
    }

    #[test]
    fn strong_noop_on_sparse_grid() {
        let prob = toy(2, 15, 40);
        let prev = prev_at_lmax(&prob);
        let lam = 0.4 * prev.lam; // 2 lam < lam_0
        let mut rule = StrongRule::new();
        let mut active = ActiveSet::full(prob.pen.groups());
        rule.begin_lambda(&prob, lam, prev.lam, Some(&prev), &mut active);
        assert_eq!(active.n_active_feats(), 40);
    }

    #[test]
    fn strong_contains_equicorrelation_at_exact_prev() {
        // With the exact previous dual point, the strong set contains every
        // group with correlation 1 (the equicorrelation set at lam_{t-1}).
        let prob = toy(3, 12, 30);
        let prev = prev_at_lmax(&prob);
        let lam = 0.95 * prev.lam;
        let strong = StrongRule::strong_active_set(&prob, &prev, lam);
        let full = ActiveSet::full(prob.pen.groups());
        let stats = prob.stats_for_center(&prev.theta, &full);
        for g in 0..prob.n_groups() {
            if stats.group_dual[g] >= 1.0 - 1e-12 {
                assert!(strong.group[g], "equicorrelated group {g} wrongly discarded");
            }
        }
    }
}
