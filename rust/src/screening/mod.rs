//! Screening rules (Sec. 3): the paper's Gap Safe rules plus every baseline
//! it compares against.
//!
//! # The Gap Safe sphere in one page
//!
//! All safe rules here are *sphere tests*: a region of the dual space that
//! provably contains the dual optimum `theta_hat` is intersected with the
//! unit sub-level sets of the group dual norms, and every group whose set
//! cannot be touched is provably zero at the primal optimum (Prop. 4).
//! The crate implements spheres `B(theta_c, r)`; a rule is a choice of
//! center and radius.
//!
//! **Dual feasible point (Eq. 9 / 18).** Any primal iterate `beta` yields
//! the generalized residual `rho = -G(X beta)`; rescaling
//!
//! ```text
//! theta = rho / max(lambda, Omega^D(X^T rho))
//! ```
//!
//! is dual feasible, and the dual norm is evaluated on the safe active set
//! only (the argmax provably lies inside it, Sec. 2.2.2).
//!
//! **Gap radius (Thm. 2).** With `gamma` the strong-smoothness constant of
//! the data fit (`gamma = 1` quadratic, `4` logistic, `1` multinomial,
//! Table 1) and `gap = P_lambda(beta) - D_lambda(theta) >= 0`,
//!
//! ```text
//! r_lambda(beta, theta) = sqrt(2 * gap) / (lambda * sqrt(gamma))
//! ```
//!
//! so `theta_hat in B(theta, r)` — the sphere shrinks to a point as the
//! solver converges, which is what makes the dynamic rule *converging*
//! (Prop. 5-6).
//!
//! **Screening test per penalty (Eq. 8, Prop. 8).** Group `g` is safely
//! discarded when the sphere stays strictly inside the dual unit ball of
//! its group norm:
//!
//! * Lasso (`Omega = l1`): `|x_j^T theta| + r * ||x_j||_2 < 1`;
//! * (multi-task) Group Lasso (`l1/l2`):
//!   `||X_g^T theta||_2 + r * ||X_g||_2 < 1` (spectral norm slope);
//! * Sparse-Group Lasso: two-level epsilon-norm tests — the group test
//!   uses `||S_tau(X_g^T theta)||_2` with slope `tau + (1-tau) w_g` bounds
//!   (Prop. 8), and surviving groups still screen individual features via
//!   `|x_j^T theta| + r * ||x_j||_2 < tau`.
//!
//! The implementations live in each [`crate::penalty::Penalty`]'s
//! `sphere_screen`; the margin constant
//! [`crate::penalty::SCREEN_MARGIN`] keeps the strict inequality safe
//! under floating-point rounding.
//!
//! # Where rules plug into the solver
//!
//! A rule interacts with the solver at two points:
//!
//! * [`ScreeningRule::begin_lambda`] — once per regularization parameter,
//!   before any iteration; *static* and *sequential* rules (Sec. 3.1-3.2)
//!   and the un-safe *strong* rule (Sec. 3.6) act here, using only
//!   quantities available from the previous path point.
//! * [`ScreeningRule::on_gap_pass`] — every `f_ce` epochs, right after the
//!   solver computed a duality gap (Alg. 2); *dynamic* rules
//!   (Sec. 3.3) act here with the current dual feasible point.
//!
//! Rules only ever *deactivate* groups; for safe rules deactivation is
//! permanent within a lambda (a safely screened group is provably zero at
//! the optimum). The strong rule is un-safe, so the solver re-checks KKT
//! conditions at convergence and reactivates violators
//! ([`ScreeningRule::needs_kkt_check`]).
//!
//! The O(np) correlation stage feeding these tests fans out over the
//! worker pool when the owning [`crate::problem::Problem`] has
//! `set_screen_threads > 1` (see [`crate::solver::parallel`]).
//!
//! # Working-set compaction
//!
//! Screening only pays off if the solver stops *touching* what it
//! screened. The CD solver therefore maintains a physically repacked
//! working design ([`crate::linalg::compact::CompactDesign`]): whenever a
//! screening event kills more than ~25% of the columns the current view
//! still carries, the surviving columns are copied into a fresh
//! contiguous matrix (dense copy or CSC slice) with an index map and
//! cached column norms, and every subsequent CD epoch, gap pass and
//! screening sweep iterates that small matrix instead of bitmap-skipping
//! through the full design (the working-set idea of Blitz / celer-style
//! active-set solvers).
//!
//! **When repacking triggers.** The view packs whole *live groups* (an
//! SGL feature screened inside a still-active group stays in the view —
//! the CD epoch visits every feature of an active group either way) and
//! is rebuilt only when the surviving column count drops below 75% of the
//! view's current width, so the total packing cost of a solve is
//! geometrically bounded by a small multiple of one full column copy.
//!
//! **Why safety is preserved.** Compaction is purely an iteration-space
//! change: packed columns hold the very same values, every per-column
//! kernel (`col_dot`, `col_axpy`, the fused gradient dot) runs the same
//! arithmetic in the same order, and the view only ever serves active
//! sets that are *subsets* of the set it was packed from (safe rules only
//! deactivate within a lambda; the KKT repair of the un-safe strong rule
//! re-activates groups, and the solver drops the view there and repacks
//! later). Solver tests pin packed vs. full paths bit-for-bit — the
//! sphere tests see identical statistics, so every Gap Safe certificate
//! is untouched.
//!
//! # Dual points
//!
//! Every sphere above is built from a dual feasible point, and Thm. 2
//! accepts *any* such point: for every feasible pair `(beta, theta)`,
//!
//! ```text
//! theta_hat in B(theta, sqrt(2 gap(beta, theta)) / (lambda sqrt(gamma)))
//! ```
//!
//! The plain rescaling `Theta(rho)` (Eq. 18) rebuilds `theta` from the
//! current residual at every pass and forgets it. Because the map from
//! iterates to dual points is not monotone in the dual objective, the
//! reported gap — and with it the Gap Safe radius — can *increase*
//! between passes even though the primal only decreases.
//!
//! The [`dual`] module fixes the frame: a [`DualPoint`] tracker keeps the
//! point with the **best dual objective seen so far** at the current
//! lambda and reports `argmax {D(kept), D(fresh)}` (strategy `best`), or
//! additionally line-searches convex combinations of the two (strategy
//! `refine`; the dual feasible set is convex, so every combination is
//! feasible). Two consequences, both pinned by tests:
//!
//! * **monotone radii** — the reported dual is non-decreasing by
//!   construction, the CD primal is non-increasing, so the reported gap
//!   and the radius `r = sqrt(2 gap)/(lambda sqrt(gamma))` are
//!   non-increasing across the gap passes of one lambda: screening can
//!   only get tighter, never bounce back;
//! * **better sequential spheres** — the `PrevSolution::theta` handed to
//!   the next path point is the tracker's pick, so the sequential rule
//!   (Eq. 15-17) centers its sphere at the best dual point of the
//!   previous lambda rather than whatever the last pass produced.
//!
//! Safety is unchanged: the kept point is feasible, its gap against the
//! current primal is a valid Thm. 2 input, and the kept correlations
//! `X^T theta` (reused so no extra O(np) sweep is paid) are exact for
//! `best` and within ~1 ulp for `refine` combinations — absorbed by the
//! conservative [`crate::penalty::SCREEN_MARGIN`]. The strategy is
//! selected by `SolveOptions::dual` / `PathConfig::dual` / CLI `--dual`
//! (default `best`; `rescale` reproduces the historical output bit for
//! bit).
//!
//! # Locally bounded duals
//!
//! The Thm. 2 radius needs the data fit to be `gamma`-strongly smooth
//! *globally* — equivalently, its conjugate `gamma`-strongly convex on the
//! whole dual space. The Poisson/KL fit has no such constant: the
//! conjugate of `e^z - y z` is `v ln v - v` at `v = u + y`, whose
//! curvature `1/v` vanishes as `v` grows, so `sup gamma = 0` and the
//! global formula degenerates (an "infinite-gamma" radius of 0 would
//! screen everything, unsafely). Following Dantas, Soubies & Fevotte
//! (2021, *Expanding Boundaries of Gap Safe Screening*), the crate uses
//! the **locally bounded** variant instead: strong convexity only needs to
//! hold on a ball `B(theta_c, r)` that already contains `theta_hat`. On
//! that ball every conjugate argument satisfies
//! `v_i <= v_max + lambda r` with `v_max = max_i (y_i - lambda
//! theta_c,i)_+`, the local strong-convexity modulus is
//! `1 / (v_max + lambda r)`, and plugging it into Thm. 2 turns the radius
//! into a fixed point `lambda^2 r^2 = 2 gap (v_max + lambda r)` with the
//! closed-form solution
//!
//! ```text
//! r = (gap + sqrt(gap^2 + 2 gap v_max)) / lambda
//! ```
//!
//! — still `O(sqrt(gap))` as the solver converges, so the dynamic rule
//! keeps its converging-screening property. Mechanically this is the
//! [`crate::datafit::DataFit::gap_safe_radius`] hook: Table-1 fits keep
//! the default (the verbatim global formula, bit for bit), while the
//! Poisson fit overrides it with the per-center bound above — every
//! sphere site (dynamic gap passes, the sequential rule, the static gap
//! rule at `theta_max`) passes its own center through the hook.

pub mod dual;

mod baselines;
mod gap_safe;
mod strong;

pub use baselines::{Dst3Rule, DynamicBonnefoyRule, StaticElGhaouiRule, StaticGapRule};
pub use dual::{DualPoint, DualStrategy};
pub use gap_safe::{GapSafeRule, GapSafeVariant};
pub use strong::StrongRule;

use crate::linalg::Mat;
use crate::penalty::{ActiveSet, ScreenStats};
use crate::problem::{GapResult, Problem};

/// Everything the path driver hands a rule about the previous path point
/// (lambda_{t-1}); see Sec. 3.2 / 3.4.
#[derive(Debug, Clone)]
pub struct PrevSolution {
    pub lam: f64,
    /// Approximate primal solution at lambda_{t-1}.
    pub beta: Mat,
    /// Cached prediction X beta.
    pub z: Mat,
    /// Rescaled dual point theta-check at lambda_{t-1}.
    pub theta: Mat,
    /// F(beta) (loss part of the primal, lambda-independent).
    pub loss: f64,
    /// Omega(beta).
    pub pen_value: f64,
    /// Safe active set at convergence of lambda_{t-1}.
    pub active: ActiveSet,
}

/// A screening strategy.
pub trait ScreeningRule: Send {
    fn name(&self) -> &'static str;

    /// Screening performed before any iteration at a new lambda.
    fn begin_lambda(
        &mut self,
        prob: &Problem,
        lam: f64,
        lam_max: f64,
        prev: Option<&PrevSolution>,
        active: &mut ActiveSet,
    );

    /// Screening performed at each duality-gap evaluation.
    fn on_gap_pass(
        &mut self,
        prob: &Problem,
        lam: f64,
        gap: &GapResult,
        active: &mut ActiveSet,
    );

    /// Whether the solver must run a KKT post-convergence check (un-safe rules).
    fn needs_kkt_check(&self) -> bool {
        false
    }
}

/// Named rule selection (CLI / experiments).
///
/// Every rule round-trips through [`Rule::parse`] / [`Rule::label`]:
///
/// ```
/// use gapsafe::screening::Rule;
///
/// assert_eq!(Rule::parse("gap").unwrap(), Rule::GapSafeFull);
/// assert_eq!(Rule::parse("gap-dyn").unwrap(), Rule::GapSafeDyn);
/// assert_eq!(Rule::parse("strong").unwrap().label(), "strong");
/// for rule in Rule::ALL {
///     assert_eq!(Rule::parse(rule.label()).unwrap(), rule);
/// }
/// assert!(Rule::parse("bogus").is_err());
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rule {
    /// No screening (baseline).
    None,
    /// Static Gap Safe sphere at theta_max (Eq. 12-14).
    StaticGap,
    /// Static El Ghaoui sphere (regression only, Sec. 3.6).
    StaticElGhaoui,
    /// Dynamic ST3 (regression only; Xiang et al. / Bonnefoy et al.).
    Dst3,
    /// Bonnefoy dynamic sphere centered at y/lambda (regression only).
    DynamicBonnefoy,
    /// Gap Safe, sequential only (Eq. 15-17).
    GapSafeSeq,
    /// Gap Safe, dynamic only (Eq. 19-21).
    GapSafeDyn,
    /// Gap Safe, sequential + dynamic (the paper's full rule).
    GapSafeFull,
    /// Strong rule (un-safe, Eq. 23-24) + dynamic Gap Safe + KKT checking.
    Strong,
}

impl Rule {
    pub const ALL: [Rule; 9] = [
        Rule::None,
        Rule::StaticGap,
        Rule::StaticElGhaoui,
        Rule::Dst3,
        Rule::DynamicBonnefoy,
        Rule::GapSafeSeq,
        Rule::GapSafeDyn,
        Rule::GapSafeFull,
        Rule::Strong,
    ];

    pub fn parse(s: &str) -> Result<Rule, String> {
        match s {
            "none" | "no-screening" => Ok(Rule::None),
            "static-gap" | "static" => Ok(Rule::StaticGap),
            "static-elghaoui" | "elghaoui" | "safe" => Ok(Rule::StaticElGhaoui),
            "dst3" | "st3" => Ok(Rule::Dst3),
            "bonnefoy" | "dynamic-safe" => Ok(Rule::DynamicBonnefoy),
            "gap-seq" | "gap-sequential" => Ok(Rule::GapSafeSeq),
            "gap-dyn" | "gap-dynamic" => Ok(Rule::GapSafeDyn),
            "gap" | "gap-full" | "gap-safe" => Ok(Rule::GapSafeFull),
            "strong" => Ok(Rule::Strong),
            other => Err(format!("unknown rule '{other}'")),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Rule::None => "no-screening",
            Rule::StaticGap => "static-gap",
            Rule::StaticElGhaoui => "static-elghaoui",
            Rule::Dst3 => "dst3",
            Rule::DynamicBonnefoy => "bonnefoy",
            Rule::GapSafeSeq => "gap-seq",
            Rule::GapSafeDyn => "gap-dyn",
            Rule::GapSafeFull => "gap-full",
            Rule::Strong => "strong",
        }
    }

    /// Instantiate the rule's state machine.
    pub fn build(&self) -> Box<dyn ScreeningRule> {
        match self {
            Rule::None => Box::new(NoScreening),
            Rule::StaticGap => Box::new(StaticGapRule::new()),
            Rule::StaticElGhaoui => Box::new(StaticElGhaouiRule::new()),
            Rule::Dst3 => Box::new(Dst3Rule::new()),
            Rule::DynamicBonnefoy => Box::new(DynamicBonnefoyRule::new()),
            Rule::GapSafeSeq => Box::new(GapSafeRule::new(GapSafeVariant::Sequential)),
            Rule::GapSafeDyn => Box::new(GapSafeRule::new(GapSafeVariant::Dynamic)),
            Rule::GapSafeFull => Box::new(GapSafeRule::new(GapSafeVariant::Full)),
            Rule::Strong => Box::new(StrongRule::new()),
        }
    }

    /// Rules that only apply to quadratic fits (Remark 9).
    pub fn regression_only(&self) -> bool {
        matches!(self, Rule::StaticElGhaoui | Rule::Dst3 | Rule::DynamicBonnefoy)
    }
}

/// The no-op baseline.
pub struct NoScreening;

impl ScreeningRule for NoScreening {
    fn name(&self) -> &'static str {
        "no-screening"
    }

    fn begin_lambda(
        &mut self,
        _prob: &Problem,
        _lam: f64,
        _lam_max: f64,
        _prev: Option<&PrevSolution>,
        _active: &mut ActiveSet,
    ) {
    }

    fn on_gap_pass(
        &mut self,
        _prob: &Problem,
        _lam: f64,
        _gap: &GapResult,
        _active: &mut ActiveSet,
    ) {
    }
}

/// Shared helper: apply a sphere test given precomputed center stats and a
/// radius, returning kills. This is the single choke point every sphere
/// site goes through, so it also owns the provenance ledger: when a trace
/// sink is installed, each application that discards columns emits one
/// `SphereCenter` (the dual point `center`, bitwise) plus one `ScreenCol`
/// per discarded feature carrying the exact inequality that fired —
/// re-checkable offline by `gapsafe trace verify`. `site` labels the
/// emission point ("seq" pre-solve, "dyn" gap pass). Screened-column
/// counters for `/metrics` are bumped regardless of tracing. Nothing here
/// feeds back into the screening decision — sink on/off stays
/// bitwise-transparent.
pub(crate) fn apply_sphere(
    prob: &Problem,
    stats: &ScreenStats,
    radius: f64,
    center: &Mat,
    rule: &'static str,
    site: &'static str,
    active: &mut ActiveSet,
) -> (usize, usize) {
    use crate::obs::{self, ledger, Event};
    if !(obs::enabled() && ledger::emit_enabled()) {
        let (kg, kf) = prob.pen.sphere_screen(stats, radius, &prob.norms, active, None);
        ledger::count_screened(rule, kf);
        return (kg, kf);
    }
    let mut recs = Vec::new();
    let (kg, kf) = prob.pen.sphere_screen(stats, radius, &prob.norms, active, Some(&mut recs));
    ledger::count_screened(rule, kf);
    if !recs.is_empty() {
        let (sid, lam, epoch) = ledger::current();
        let cid = ledger::next_id();
        obs::emit(&Event::SphereCenter {
            sid,
            cid,
            lam,
            epoch,
            rule,
            site,
            radius,
            n: center.rows(),
            q: center.cols(),
            theta: center.as_slice().to_vec(),
        });
        for rec in recs {
            obs::emit(&Event::ScreenCol {
                sid,
                cid,
                lam,
                epoch,
                rule,
                test: rec.test,
                j: rec.j,
                group: rec.group,
                stat: rec.stat,
                norm: rec.norm,
                radius,
                thresh: rec.thresh,
                margin: rec.thresh - rec.stat - radius * rec.norm,
            });
        }
    }
    (kg, kf)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_labels_roundtrip() {
        for r in Rule::ALL {
            assert_eq!(Rule::parse(r.label()).unwrap(), r);
        }
        assert!(Rule::parse("bogus").is_err());
    }

    #[test]
    fn regression_only_flags() {
        assert!(Rule::StaticElGhaoui.regression_only());
        assert!(Rule::Dst3.regression_only());
        assert!(Rule::DynamicBonnefoy.regression_only());
        assert!(!Rule::GapSafeFull.regression_only());
        assert!(!Rule::Strong.regression_only());
    }
}
