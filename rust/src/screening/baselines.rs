//! Baseline safe rules the paper compares against (Sec. 3.1 and 3.6):
//! the static Gap sphere, El Ghaoui's seminal static sphere, ST3/DST3 and
//! Bonnefoy's dynamic sphere. The last three exploit
//! theta-hat = Pi_{Delta_X}(y/lambda) and are therefore *regression only*
//! (Remark 9); they are no-ops on non-quadratic fits.
//!
//! All four reuse the generic sphere test of the
//! [module docs](crate::screening) — only the (center, radius) pair
//! changes; none of them shrink with the iterates the way the dynamic Gap
//! Safe sphere does, which is the comparison Figs. 3-6 quantify.

use super::{apply_sphere, PrevSolution, ScreeningRule};
use crate::datafit::FitKind;
use crate::linalg::{dot, norm2, norm_sq, Mat};
use crate::penalty::{ActiveSet, PenaltyKind, ScreenStats};
use crate::problem::{GapResult, Problem};

/// Static Gap Safe sphere (Eq. 12-14): center theta_max = -G(0)/lambda_max,
/// radius r_lambda(0, theta_max). Screens once per lambda, before iterating.
pub struct StaticGapRule {
    pub screened_groups: usize,
}

impl StaticGapRule {
    pub fn new() -> Self {
        StaticGapRule { screened_groups: 0 }
    }
}

impl Default for StaticGapRule {
    fn default() -> Self {
        Self::new()
    }
}

impl ScreeningRule for StaticGapRule {
    fn name(&self) -> &'static str {
        "static-gap"
    }

    fn begin_lambda(
        &mut self,
        prob: &Problem,
        lam: f64,
        lam_max: f64,
        _prev: Option<&PrevSolution>,
        active: &mut ActiveSet,
    ) {
        let (n, q) = (prob.n(), prob.q());
        let z0 = Mat::zeros(n, q);
        let mut theta_max = Mat::zeros(n, q);
        prob.fit.neg_grad(&z0, &mut theta_max);
        theta_max.as_mut_slice().iter_mut().for_each(|v| *v /= lam_max);
        // Gap at (beta = 0, theta_max): P_lambda(0) = F(0), Omega(0) = 0.
        let primal = prob.fit.loss(&z0);
        let dual = prob.fit.dual(&theta_max, lam);
        let gap = (primal - dual).max(0.0);
        // Curvature hook: bitwise-identical global-gamma radius for the
        // Table-1 fits, per-center local bound for Poisson (theta_max is
        // dual feasible for it: v = y (1 - lam/lam_max) + lam/lam_max >= 0).
        let radius = prob.fit.gap_safe_radius(gap, lam, &theta_max);
        let full = ActiveSet::full(prob.pen.groups());
        let stats = prob.stats_for_center(&theta_max, &full);
        let (kg, _) = apply_sphere(prob, &stats, radius, &theta_max, self.name(), "seq", active);
        self.screened_groups += kg;
    }

    fn on_gap_pass(&mut self, _: &Problem, _: f64, _: &GapResult, _: &mut ActiveSet) {}
}

/// El Ghaoui et al. (2012) static sphere for regression: center y/lambda,
/// radius |1/lambda - 1/lambda_max| ||y|| (Sec. 3.1 / 3.6). Exhibits the
/// lambda_critic dead zone measured in the ablation bench.
pub struct StaticElGhaouiRule {
    pub screened_groups: usize,
}

impl StaticElGhaouiRule {
    pub fn new() -> Self {
        StaticElGhaouiRule { screened_groups: 0 }
    }

    /// The threshold lambda_critic below which this rule cannot screen
    /// (closed form of Sec. 3.1 for the (group) Lasso).
    pub fn lambda_critic(prob: &Problem, lam_max: f64) -> f64 {
        let y = prob.fit.targets();
        let ynorm = y.frob_sq().sqrt();
        let full = ActiveSet::full(prob.pen.groups());
        // Omega_g^D(X_g^T G(0)) with G(0) = -y for regression.
        let stats = {
            let mut my = y.clone();
            my.as_mut_slice().iter_mut().for_each(|v| *v = -*v);
            prob.stats_for_center(&my, &full)
        };
        let mut crit: f64 = f64::INFINITY;
        for g in 0..prob.n_groups() {
            let opn = prob.norms.op[g];
            let denom = lam_max + ynorm * opn - stats.group_dual[g];
            if denom > 0.0 {
                crit = crit.min(lam_max * ynorm * opn / denom);
            }
        }
        crit
    }
}

impl Default for StaticElGhaouiRule {
    fn default() -> Self {
        Self::new()
    }
}

impl ScreeningRule for StaticElGhaouiRule {
    fn name(&self) -> &'static str {
        "static-elghaoui"
    }

    fn begin_lambda(
        &mut self,
        prob: &Problem,
        lam: f64,
        lam_max: f64,
        _prev: Option<&PrevSolution>,
        active: &mut ActiveSet,
    ) {
        if prob.fit.kind() != FitKind::Quadratic {
            return; // regression-only rule (Remark 9)
        }
        let y = prob.fit.targets();
        let mut center = y.clone();
        center.as_mut_slice().iter_mut().for_each(|v| *v /= lam);
        let radius = (1.0 / lam - 1.0 / lam_max).abs() * y.frob_sq().sqrt();
        let full = ActiveSet::full(prob.pen.groups());
        let stats = prob.stats_for_center(&center, &full);
        let (kg, _) = apply_sphere(prob, &stats, radius, &center, self.name(), "seq", active);
        self.screened_groups += kg;
    }

    fn on_gap_pass(&mut self, _: &Problem, _: f64, _: &GapResult, _: &mut ActiveSet) {}
}

/// Bonnefoy et al. dynamic sphere: center y/lambda, radius
/// ||y/lambda - theta_k|| with the current dual feasible point theta_k
/// (Sec. 3.3 / 3.6). Non-converging: the radius is bounded below by
/// ||y/lambda - theta_hat|| (Remark 10).
pub struct DynamicBonnefoyRule {
    /// The fixed center y/lambda and its stats, cached per lambda (the
    /// center itself is kept for the provenance ledger).
    cached: Option<(f64, Mat, ScreenStats)>,
    pub screened_groups: usize,
}

impl DynamicBonnefoyRule {
    pub fn new() -> Self {
        DynamicBonnefoyRule { cached: None, screened_groups: 0 }
    }
}

impl Default for DynamicBonnefoyRule {
    fn default() -> Self {
        Self::new()
    }
}

impl ScreeningRule for DynamicBonnefoyRule {
    fn name(&self) -> &'static str {
        "bonnefoy"
    }

    fn begin_lambda(
        &mut self,
        prob: &Problem,
        lam: f64,
        _lam_max: f64,
        _prev: Option<&PrevSolution>,
        _active: &mut ActiveSet,
    ) {
        if prob.fit.kind() != FitKind::Quadratic {
            self.cached = None;
            return;
        }
        let y = prob.fit.targets();
        let mut center = y.clone();
        center.as_mut_slice().iter_mut().for_each(|v| *v /= lam);
        let full = ActiveSet::full(prob.pen.groups());
        let stats = prob.stats_for_center(&center, &full);
        self.cached = Some((lam, center, stats));
    }

    fn on_gap_pass(
        &mut self,
        prob: &Problem,
        lam: f64,
        gap: &GapResult,
        active: &mut ActiveSet,
    ) {
        let Some((clam, center, stats)) = &self.cached else { return };
        if (*clam - lam).abs() > 1e-15 {
            return;
        }
        // radius = ||y/lambda - theta_k||_F
        let y = prob.fit.targets();
        let mut rsq = 0.0;
        for (yi, ti) in y.as_slice().iter().zip(gap.theta.as_slice()) {
            let d = yi / lam - ti;
            rsq += d * d;
        }
        let center = center.clone();
        let stats = stats.clone();
        let (kg, _) =
            apply_sphere(prob, &stats, rsq.sqrt(), &center, self.name(), "dyn", active);
        self.screened_groups += kg;
    }
}

/// ST3 / dynamic ST3 (Xiang et al. 2011; Bonnefoy et al. 2014-15):
/// center = projection of y/lambda onto the active hyperplane of the most
/// correlated group g*, radius shrunk accordingly (Sec. 3.6).
///
/// Implemented for the l1 and l1/l2 (q = 1) penalties where the dual-norm
/// gradient has a closed form; for SGL the rule of Ndiaye et al. (2016b,
/// App. D) reduces to the same construction with the epsilon-norm gradient
/// — we conservatively fall back to the Bonnefoy sphere there (safe, just
/// looser).
pub struct Dst3Rule {
    /// (lambda, center stats, ||y/lam - theta_c||^2, center) cache.
    cached: Option<Cache>,
    pub screened_groups: usize,
}

struct Cache {
    lam: f64,
    /// The sphere center (projection theta_c, or y/lambda for the
    /// Bonnefoy fallback), kept for the provenance ledger.
    center: Mat,
    stats: ScreenStats,
    /// ||y/lambda - theta_c||^2 (0 for the Bonnefoy fallback).
    shift_sq: f64,
    /// true when the projection construction applied.
    projected: bool,
}

impl Dst3Rule {
    pub fn new() -> Self {
        Dst3Rule { cached: None, screened_groups: 0 }
    }
}

impl Default for Dst3Rule {
    fn default() -> Self {
        Self::new()
    }
}

impl ScreeningRule for Dst3Rule {
    fn name(&self) -> &'static str {
        "dst3"
    }

    fn begin_lambda(
        &mut self,
        prob: &Problem,
        lam: f64,
        _lam_max: f64,
        _prev: Option<&PrevSolution>,
        _active: &mut ActiveSet,
    ) {
        self.cached = None;
        if prob.fit.kind() != FitKind::Quadratic || prob.q() != 1 {
            return;
        }
        let y: Vec<f64> = prob.fit.targets().as_slice().to_vec();
        let n = y.len();
        let full = ActiveSet::full(prob.pen.groups());
        // g* = argmax_g Omega_g^D(X_g^T y)
        let ystats = prob.stats_for_center(prob.fit.targets(), &full);
        let mut gstar = 0usize;
        for g in 1..prob.n_groups() {
            if ystats.group_dual[g] > ystats.group_dual[gstar] {
                gstar = g;
            }
        }
        let lam_max_val = ystats.group_dual[gstar];
        let feats = prob.pen.groups().feats(gstar).to_vec();
        // eta = X_{g*} grad Omega^D_{g*}(X_{g*}^T y / lambda_max)
        let mut eta = vec![0.0; n];
        let supported = match prob.pen.kind() {
            PenaltyKind::L1 => {
                let j = feats[0];
                let c = prob.x.col_dot(j, &y);
                prob.x.col_axpy(j, c.signum(), &mut eta);
                true
            }
            PenaltyKind::GroupL2 => {
                // grad of ||v||_2 / w at v: v / (w ||v||); constants cancel in
                // the projection, so use v / ||v||.
                let mut v: Vec<f64> = feats.iter().map(|&j| prob.x.col_dot(j, &y)).collect();
                let nv = norm2(&v);
                if nv > 0.0 {
                    v.iter_mut().for_each(|c| *c /= nv);
                    for (i, &j) in feats.iter().enumerate() {
                        prob.x.col_axpy(j, v[i], &mut eta);
                    }
                    true
                } else {
                    false
                }
            }
            PenaltyKind::SparseGroup => false,
        };
        let yl: Vec<f64> = y.iter().map(|v| v / lam).collect();
        if !supported || lam_max_val <= 0.0 {
            // Bonnefoy fallback: center y/lambda.
            let center = Mat::col_vec(&yl);
            let stats = prob.stats_for_center(&center, &full);
            self.cached =
                Some(Cache { lam, center, stats, shift_sq: 0.0, projected: false });
            return;
        }
        // theta_c = y/lam - ((<y/lam, eta> - 1) / ||eta||^2) eta
        let ee = norm_sq(&eta);
        let coef = (dot(&yl, &eta) - 1.0) / ee;
        let mut center = yl.clone();
        for i in 0..n {
            center[i] -= coef * eta[i];
        }
        let shift_sq = coef * coef * ee; // ||y/lam - theta_c||^2
        let center = Mat::col_vec(&center);
        let stats = prob.stats_for_center(&center, &full);
        self.cached = Some(Cache { lam, center, stats, shift_sq, projected: true });
    }

    fn on_gap_pass(
        &mut self,
        prob: &Problem,
        lam: f64,
        gap: &GapResult,
        active: &mut ActiveSet,
    ) {
        let Some(cache) = &self.cached else { return };
        if (cache.lam - lam).abs() > 1e-15 {
            return;
        }
        // r_theta = sqrt(||y/lam - theta_k||^2 - ||y/lam - theta_c||^2)
        let y = prob.fit.targets();
        let mut dist_sq = 0.0;
        for (yi, ti) in y.as_slice().iter().zip(gap.theta.as_slice()) {
            let d = yi / lam - ti;
            dist_sq += d * d;
        }
        let r_sq = if cache.projected { (dist_sq - cache.shift_sq).max(0.0) } else { dist_sq };
        let center = cache.center.clone();
        let stats = cache.stats.clone();
        let (kg, _) =
            apply_sphere(prob, &stats, r_sq.sqrt(), &center, self.name(), "dyn", active);
        self.screened_groups += kg;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datafit::Quadratic;
    use crate::linalg::sparse::Design;
    use crate::penalty::{Groups, L1};
    use crate::problem::Problem;
    use crate::util::prng::Prng;

    fn toy(seed: u64, n: usize, p: usize) -> Problem {
        let mut rng = Prng::new(seed);
        let mut x = Mat::zeros(n, p);
        for v in x.as_mut_slice() {
            *v = rng.gaussian();
        }
        let y: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        Problem::new(Design::Dense(x), Box::new(Quadratic::from_vec(&y)), Box::new(L1::new(p)))
    }

    #[test]
    fn static_rules_screen_near_lambda_max() {
        let prob = toy(1, 15, 50);
        let lmax = prob.lambda_max();
        let lam = 0.98 * lmax;
        for mut rule in [
            Box::new(StaticGapRule::new()) as Box<dyn ScreeningRule>,
            Box::new(StaticElGhaouiRule::new()),
        ] {
            let mut active = ActiveSet::full(prob.pen.groups());
            rule.begin_lambda(&prob, lam, lmax, None, &mut active);
            assert!(
                active.n_active_feats() < 50,
                "{} screened nothing at 0.98 lambda_max",
                rule.name()
            );
        }
    }

    #[test]
    fn static_rules_useless_at_small_lambda() {
        // The lambda_critic phenomenon: far below lambda_max the static
        // El Ghaoui radius blows up and nothing can be screened.
        let prob = toy(2, 15, 50);
        let lmax = prob.lambda_max();
        let lam = lmax / 100.0;
        let mut rule = StaticElGhaouiRule::new();
        let mut active = ActiveSet::full(prob.pen.groups());
        rule.begin_lambda(&prob, lam, lmax, None, &mut active);
        assert_eq!(active.n_active_feats(), 50);
        let crit = StaticElGhaouiRule::lambda_critic(&prob, lmax);
        assert!(crit > lam, "lambda_critic {crit} should exceed {lam}");
        assert!(crit < lmax);
    }

    #[test]
    fn bonnefoy_and_dst3_screen_with_good_theta() {
        let prob = toy(3, 20, 60);
        let lmax = prob.lambda_max();
        let lam = 0.9 * lmax;
        let beta = Mat::zeros(60, 1);
        let z = prob.predict(&beta);
        let full = ActiveSet::full(prob.pen.groups());
        let gap = prob.gap_pass(&beta, &z, lam, &full);
        for (name, mut rule) in [
            ("bonnefoy", Box::new(DynamicBonnefoyRule::new()) as Box<dyn ScreeningRule>),
            ("dst3", Box::new(Dst3Rule::new())),
        ] {
            let mut active = ActiveSet::full(prob.pen.groups());
            rule.begin_lambda(&prob, lam, lmax, None, &mut active);
            rule.on_gap_pass(&prob, lam, &gap, &mut active);
            assert!(active.n_active_feats() < 60, "{name} screened nothing");
        }
    }

    #[test]
    fn dst3_at_least_as_tight_as_bonnefoy() {
        // Same theta_k: DST3's sphere is contained in Bonnefoy's, so it must
        // screen at least as many features.
        let prob = toy(4, 18, 80);
        let lmax = prob.lambda_max();
        let lam = 0.85 * lmax;
        let beta = Mat::zeros(80, 1);
        let z = prob.predict(&beta);
        let full = ActiveSet::full(prob.pen.groups());
        let gap = prob.gap_pass(&beta, &z, lam, &full);
        let mut ab = ActiveSet::full(prob.pen.groups());
        let mut ad = ActiveSet::full(prob.pen.groups());
        let mut rb = DynamicBonnefoyRule::new();
        let mut rd = Dst3Rule::new();
        rb.begin_lambda(&prob, lam, lmax, None, &mut ab);
        rd.begin_lambda(&prob, lam, lmax, None, &mut ad);
        rb.on_gap_pass(&prob, lam, &gap, &mut ab);
        rd.on_gap_pass(&prob, lam, &gap, &mut ad);
        assert!(ad.n_active_feats() <= ab.n_active_feats());
    }

    #[test]
    fn regression_only_rules_noop_on_logistic() {
        use crate::datafit::Logistic;
        let mut rng = Prng::new(5);
        let mut x = Mat::zeros(12, 20);
        for v in x.as_mut_slice() {
            *v = rng.gaussian();
        }
        let y: Vec<f64> = (0..12).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect();
        let prob = Problem::new(
            Design::Dense(x),
            Box::new(Logistic::new(&y)),
            Box::new(L1::new(20)),
        );
        let lmax = prob.lambda_max();
        let mut rule = StaticElGhaouiRule::new();
        let mut active = ActiveSet::full(prob.pen.groups());
        rule.begin_lambda(&prob, 0.9 * lmax, lmax, None, &mut active);
        assert_eq!(active.n_active_feats(), 20, "must not screen on logistic");
    }

    #[test]
    fn dst3_group_lasso_path_supported() {
        use crate::datafit::Quadratic;
        use crate::penalty::GroupL2;
        let mut rng = Prng::new(6);
        let mut x = Mat::zeros(14, 24);
        for v in x.as_mut_slice() {
            *v = rng.gaussian();
        }
        let y: Vec<f64> = (0..14).map(|_| rng.gaussian()).collect();
        let prob = Problem::new(
            Design::Dense(x),
            Box::new(Quadratic::from_vec(&y)),
            Box::new(GroupL2::new(Groups::contiguous(24, 3))),
        );
        let lmax = prob.lambda_max();
        let lam = 0.9 * lmax;
        let beta = Mat::zeros(24, 1);
        let z = prob.predict(&beta);
        let full = ActiveSet::full(prob.pen.groups());
        let gap = prob.gap_pass(&beta, &z, lam, &full);
        let mut rule = Dst3Rule::new();
        let mut active = ActiveSet::full(prob.pen.groups());
        rule.begin_lambda(&prob, lam, lmax, None, &mut active);
        rule.on_gap_pass(&prob, lam, &gap, &mut active);
        assert!(active.n_active_groups() < 8);
    }
}
