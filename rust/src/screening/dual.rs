//! Dual-point engine: strategies for choosing the dual feasible point a
//! gap pass reports, and the per-lambda tracker that keeps the best one.
//!
//! The Gap Safe radius `r = sqrt(2 gap) / (lambda sqrt(gamma))` (Thm. 2)
//! is only as tight as the dual point the gap is evaluated at. The plain
//! residual rescaling Theta(z) (Eq. 18) rebuilds that point from scratch
//! at every gap pass and throws it away — so the dual objective, and with
//! it the radius, can *oscillate* between passes even though the primal
//! is monotone. "Mind the duality gap" (Fercoq et al., 2015) observed
//! that any dual feasible point is admissible in Thm. 2, so keeping the
//! best one seen so far costs one comparison and makes the reported gap
//! monotonically non-increasing within a lambda.
//!
//! Three strategies, selectable via `SolveOptions::dual` /
//! `PathConfig::dual` / the CLI `--dual` flag:
//!
//! * [`DualStrategy::Rescale`] — today's behavior: report the freshly
//!   rescaled point, remember nothing. Kept bitwise-identical to the
//!   historical output so existing pins survive.
//! * [`DualStrategy::BestKept`] — remember the point with the highest
//!   dual objective seen so far at this lambda and report whichever of
//!   {kept, fresh} is better. The reported dual is non-decreasing, so
//!   the reported gap (primal is non-increasing under CD) and the Gap
//!   Safe radius are non-increasing across gap passes.
//! * [`DualStrategy::Refine`] — additionally probe a few convex
//!   combinations between the kept and the fresh point and report the
//!   combination with the largest dual objective. The dual feasible set
//!   is convex, so every combination is feasible; evaluating the dual is
//!   O(n q), negligible next to the O(n p) correlation sweep the pass
//!   already paid for.
//!
//! Safety: Thm. 2 holds for *any* primal/dual feasible pair, so a sphere
//! centered at the kept (or combined) point with the radius of its gap is
//! exactly as safe as the rescaled one — only tighter. The tracker also
//! keeps the correlations `X^T theta` of its point, so the sphere-test
//! statistics are produced without a second O(n p) sweep; for convex
//! combinations the correlations combine linearly (exactly in real
//! arithmetic, to ~1 ulp in floats — absorbed by the conservative
//! [`crate::penalty::SCREEN_MARGIN`]).

use crate::linalg::Mat;
use crate::problem::Problem;

/// How the gap pass picks the dual feasible point it reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DualStrategy {
    /// Fresh residual rescaling every pass (the historical behavior;
    /// bitwise-identical to pre-tracker output).
    Rescale,
    /// Report the best-dual point seen so far at this lambda.
    BestKept,
    /// Best-kept plus a cheap convex-combination line search between the
    /// kept and the fresh point.
    Refine,
}

impl DualStrategy {
    pub const ALL: [DualStrategy; 3] =
        [DualStrategy::Rescale, DualStrategy::BestKept, DualStrategy::Refine];

    /// Parse a CLI / request label.
    ///
    /// ```
    /// use gapsafe::screening::DualStrategy;
    ///
    /// assert_eq!(DualStrategy::parse("rescale").unwrap(), DualStrategy::Rescale);
    /// assert_eq!(DualStrategy::parse("best").unwrap(), DualStrategy::BestKept);
    /// for s in DualStrategy::ALL {
    ///     assert_eq!(DualStrategy::parse(s.label()).unwrap(), s);
    /// }
    /// assert!(DualStrategy::parse("bogus").is_err());
    /// ```
    pub fn parse(s: &str) -> Result<DualStrategy, String> {
        match s {
            "rescale" => Ok(DualStrategy::Rescale),
            "best" | "best-kept" => Ok(DualStrategy::BestKept),
            "refine" => Ok(DualStrategy::Refine),
            other => Err(format!("unknown dual strategy '{other}' (rescale|best|refine)")),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            DualStrategy::Rescale => "rescale",
            DualStrategy::BestKept => "best",
            DualStrategy::Refine => "refine",
        }
    }
}

impl Default for DualStrategy {
    /// `best`: monotone radii for one comparison per pass.
    fn default() -> Self {
        DualStrategy::BestKept
    }
}

/// The kept point: its dual objective, the point itself and its
/// correlations `X^T theta` (entries valid on every active set that is a
/// subset of the one it was recorded under — safe rules only shrink the
/// active set within a lambda; the KKT repair of un-safe rules grows it
/// and must [`DualPoint::invalidate`] the tracker).
struct BestDual {
    dual: f64,
    theta: Mat,
    corr: Mat,
}

/// Per-lambda tracker of the best dual feasible point (owned by the
/// solver state; every gap pass runs through
/// [`Problem::gap_pass_dual`], which consults this).
pub struct DualPoint {
    strategy: DualStrategy,
    /// Bit pattern of the lambda the kept point belongs to (the dual
    /// objective is lambda-dependent, so the kept point resets when the
    /// tracker is reused across path points).
    lam_bits: u64,
    best: Option<BestDual>,
    /// What the last [`DualPoint::select`] reported ("fresh" | "kept" |
    /// "refined") — a tracing/diagnostics label, never read by the math.
    last_choice: &'static str,
}

/// Interior probe points of the Refine line search (endpoints are free:
/// their duals are already known).
const REFINE_PROBES: [f64; 3] = [0.25, 0.5, 0.75];

impl DualPoint {
    pub fn new(strategy: DualStrategy) -> Self {
        DualPoint { strategy, lam_bits: f64::NAN.to_bits(), best: None, last_choice: "fresh" }
    }

    pub fn strategy(&self) -> DualStrategy {
        self.strategy
    }

    /// The last [`DualPoint::select`] decision: `"fresh"` (the rescaled
    /// candidate won or the strategy is `Rescale`), `"kept"` (the stored
    /// best point was reported) or `"refined"` (an interior convex
    /// combination won).
    pub fn last_choice(&self) -> &'static str {
        self.last_choice
    }

    /// Drop the kept point. Must be called when the active set *grows*
    /// (strong-rule KKT repair): the kept correlations are stale for
    /// reactivated groups.
    pub fn invalidate(&mut self) {
        self.best = None;
    }

    /// Whether a kept point is currently held (diagnostics / tests).
    pub fn has_kept(&self) -> bool {
        self.best.is_some()
    }

    /// Choose the reported point given the freshly rescaled candidate
    /// `(theta_new, corr_new, dual_new)` at `lam`. Returns the chosen
    /// `(theta, corr, dual)`; updates the kept point so the reported dual
    /// never decreases within a lambda (for `BestKept` / `Refine`).
    pub(crate) fn select(
        &mut self,
        prob: &Problem,
        lam: f64,
        theta_new: Mat,
        corr_new: Mat,
        dual_new: f64,
    ) -> (Mat, Mat, f64) {
        self.last_choice = "fresh";
        if self.strategy == DualStrategy::Rescale {
            // Bitwise-identical to the historical pass: hand the fresh
            // candidate straight through, remember nothing.
            return (theta_new, corr_new, dual_new);
        }
        if self.lam_bits != lam.to_bits() {
            self.best = None;
            self.lam_bits = lam.to_bits();
        }
        let Some(kept) = &self.best else {
            self.best = Some(BestDual {
                dual: dual_new,
                theta: theta_new.clone(),
                corr: corr_new.clone(),
            });
            return (theta_new, corr_new, dual_new);
        };
        // NaN guard: a degenerate fresh dual never displaces a kept point.
        let fresh_wins = dual_new >= kept.dual;
        match self.strategy {
            DualStrategy::BestKept => {
                if fresh_wins {
                    self.best = Some(BestDual {
                        dual: dual_new,
                        theta: theta_new.clone(),
                        corr: corr_new.clone(),
                    });
                    (theta_new, corr_new, dual_new)
                } else {
                    self.last_choice = "kept";
                    (kept.theta.clone(), kept.corr.clone(), kept.dual)
                }
            }
            DualStrategy::Refine => {
                // Line search over theta(t) = kept + t (fresh - kept),
                // t in {0, probes, 1}; every point is a convex combination
                // of two feasible points, hence feasible.
                let (mut best_t, mut best_d) =
                    if fresh_wins { (1.0, dual_new) } else { (0.0, kept.dual) };
                let mut scratch = Mat::zeros(theta_new.rows(), theta_new.cols());
                for &t in &REFINE_PROBES {
                    for ((s, &a), &b) in scratch
                        .as_mut_slice()
                        .iter_mut()
                        .zip(kept.theta.as_slice())
                        .zip(theta_new.as_slice())
                    {
                        *s = a + t * (b - a);
                    }
                    let d = prob.fit.dual(&scratch, lam);
                    if d > best_d {
                        best_d = d;
                        best_t = t;
                    }
                }
                if best_t == 1.0 {
                    self.best = Some(BestDual {
                        dual: dual_new,
                        theta: theta_new.clone(),
                        corr: corr_new.clone(),
                    });
                    return (theta_new, corr_new, dual_new);
                }
                if best_t == 0.0 {
                    self.last_choice = "kept";
                    return (kept.theta.clone(), kept.corr.clone(), kept.dual);
                }
                // Interior winner: materialize theta(t) and the linearly
                // combined correlations, keep it as the new best.
                let t = best_t;
                let mut theta = kept.theta.clone();
                for (s, &b) in theta.as_mut_slice().iter_mut().zip(theta_new.as_slice()) {
                    *s += t * (b - *s);
                }
                let mut corr = kept.corr.clone();
                for (s, &b) in corr.as_mut_slice().iter_mut().zip(corr_new.as_slice()) {
                    *s += t * (b - *s);
                }
                self.best = Some(BestDual {
                    dual: best_d,
                    theta: theta.clone(),
                    corr: corr.clone(),
                });
                self.last_choice = "refined";
                (theta, corr, best_d)
            }
            // Already early-returned above; keep the arm equivalent (hand
            // the fresh candidate through) instead of a reachable panic.
            DualStrategy::Rescale => (theta_new, corr_new, dual_new),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datafit::Quadratic;
    use crate::linalg::sparse::Design;
    use crate::penalty::{ActiveSet, L1};
    use crate::util::prng::Prng;

    fn toy(seed: u64, n: usize, p: usize) -> Problem {
        let mut rng = Prng::new(seed);
        let mut x = Mat::zeros(n, p);
        for v in x.as_mut_slice() {
            *v = rng.gaussian();
        }
        let y: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        Problem::new(Design::Dense(x), Box::new(Quadratic::from_vec(&y)), Box::new(L1::new(p)))
    }

    #[test]
    fn parse_labels_roundtrip_and_default() {
        for s in DualStrategy::ALL {
            assert_eq!(DualStrategy::parse(s.label()).unwrap(), s);
        }
        assert_eq!(DualStrategy::parse("best-kept").unwrap(), DualStrategy::BestKept);
        assert!(DualStrategy::parse("nope").is_err());
        assert_eq!(DualStrategy::default(), DualStrategy::BestKept);
    }

    #[test]
    fn rescale_hands_candidate_through_untouched() {
        let prob = toy(1, 8, 10);
        let mut dp = DualPoint::new(DualStrategy::Rescale);
        let theta = Mat::col_vec(&[0.1; 8]);
        let corr = Mat::col_vec(&[0.2; 10]);
        let (t2, c2, d2) = dp.select(&prob, 1.0, theta.clone(), corr.clone(), -3.5);
        assert_eq!(t2.as_slice(), theta.as_slice());
        assert_eq!(c2.as_slice(), corr.as_slice());
        assert_eq!(d2, -3.5);
        assert!(!dp.has_kept(), "rescale must remember nothing");
    }

    #[test]
    fn best_kept_reports_monotone_dual() {
        let prob = toy(2, 10, 12);
        let mut dp = DualPoint::new(DualStrategy::BestKept);
        let mk = |v: f64| (Mat::col_vec(&[v; 10]), Mat::col_vec(&[v; 12]));
        let lam = 0.7;
        let mut reported = Vec::new();
        for &d in &[1.0, 3.0, 2.0, 2.5, 4.0] {
            let (theta, corr) = mk(d);
            let (_, _, got) = dp.select(&prob, lam, theta, corr, d);
            reported.push(got);
        }
        assert_eq!(reported, vec![1.0, 3.0, 3.0, 3.0, 4.0]);
        // lambda rollover resets the kept point
        let (theta, corr) = mk(0.5);
        let (_, _, got) = dp.select(&prob, lam * 0.5, theta, corr, 0.5);
        assert_eq!(got, 0.5);
        // invalidate drops the kept point
        assert!(dp.has_kept());
        dp.invalidate();
        assert!(!dp.has_kept());
    }

    #[test]
    fn last_choice_tracks_decisions() {
        let prob = toy(7, 10, 12);
        let mut dp = DualPoint::new(DualStrategy::BestKept);
        assert_eq!(dp.last_choice(), "fresh");
        let mk = |v: f64| (Mat::col_vec(&[v; 10]), Mat::col_vec(&[v; 12]));
        let (t, c) = mk(0.1);
        let _ = dp.select(&prob, 1.0, t, c, 3.0);
        assert_eq!(dp.last_choice(), "fresh");
        let (t, c) = mk(0.2);
        let _ = dp.select(&prob, 1.0, t, c, 1.0);
        assert_eq!(dp.last_choice(), "kept");
        let (t, c) = mk(0.3);
        let _ = dp.select(&prob, 1.0, t, c, 5.0);
        assert_eq!(dp.last_choice(), "fresh");
    }

    #[test]
    fn best_kept_returns_the_kept_point_itself() {
        let prob = toy(3, 6, 8);
        let mut dp = DualPoint::new(DualStrategy::BestKept);
        let good_theta = Mat::col_vec(&[0.9; 6]);
        let good_corr = Mat::col_vec(&[0.8; 8]);
        let _ = dp.select(&prob, 1.0, good_theta.clone(), good_corr.clone(), 5.0);
        let (t, c, d) =
            dp.select(&prob, 1.0, Mat::col_vec(&[0.0; 6]), Mat::col_vec(&[0.0; 8]), 1.0);
        assert_eq!(d, 5.0);
        assert_eq!(t.as_slice(), good_theta.as_slice());
        assert_eq!(c.as_slice(), good_corr.as_slice());
    }

    #[test]
    fn refine_never_reports_below_either_endpoint() {
        // Real dual objective: refine's pick must dominate both the kept
        // and the fresh candidate by construction.
        let prob = toy(4, 12, 16);
        let lam = 0.6;
        let mut dp = DualPoint::new(DualStrategy::Refine);
        let mut rng = Prng::new(9);
        let mut prev_reported = f64::NEG_INFINITY;
        for _ in 0..6 {
            let mut theta = Mat::zeros(12, 1);
            for v in theta.as_mut_slice() {
                *v = 0.05 * rng.gaussian();
            }
            // corr = X^T theta so the kept correlations stay consistent
            let full = ActiveSet::full(prob.pen.groups());
            let mut corr = Mat::zeros(16, 1);
            prob.corr_active(&theta, &full, &mut corr);
            let d = prob.fit.dual(&theta, lam);
            let (_, _, got) = dp.select(&prob, lam, theta, corr, d);
            assert!(got >= d - 1e-15, "refine reported below the fresh candidate");
            assert!(
                got >= prev_reported - 1e-15,
                "refine dual decreased: {got} < {prev_reported}"
            );
            prev_reported = got;
        }
    }

    #[test]
    fn refine_combined_corr_matches_true_correlations() {
        // The linear combination of correlations must equal X^T theta(t)
        // to floating-point accuracy (this is what SCREEN_MARGIN absorbs).
        let prob = toy(5, 10, 14);
        let lam = 0.5;
        let mut dp = DualPoint::new(DualStrategy::Refine);
        let full = ActiveSet::full(prob.pen.groups());
        let mk = |scale: f64, seed: u64| {
            let mut rng = Prng::new(seed);
            let mut theta = Mat::zeros(10, 1);
            for v in theta.as_mut_slice() {
                *v = scale * rng.gaussian();
            }
            let mut corr = Mat::zeros(14, 1);
            prob.corr_active(&theta, &full, &mut corr);
            let d = prob.fit.dual(&theta, lam);
            (theta, corr, d)
        };
        let (t1, c1, d1) = mk(0.02, 1);
        let _ = dp.select(&prob, lam, t1, c1, d1);
        let (t2, c2, d2) = mk(0.03, 2);
        let (theta_sel, corr_sel, _) = dp.select(&prob, lam, t2, c2, d2);
        let mut true_corr = Mat::zeros(14, 1);
        prob.corr_active(&theta_sel, &full, &mut true_corr);
        for j in 0..14 {
            assert!(
                (corr_sel[(j, 0)] - true_corr[(j, 0)]).abs() < 1e-12,
                "combined corr diverged at {j}"
            );
        }
    }
}
