//! The paper's contribution: Gap Safe spheres (Thm. 2) applied statically,
//! sequentially (Eq. 15-17) and dynamically (Eq. 19-21).
//!
//! Both variants use the radius of Thm. 2,
//! `r = sqrt(2 * gap) / (lambda * sqrt(gamma))` (see the
//! [module docs](crate::screening) for the full sphere math):
//!
//! * *sequential* — center `theta_{t-1}` (the dual point kept from the
//!   previous path point; with the default `dual = best` strategy this is
//!   the *best* dual point that lambda ever saw, not whatever the last
//!   pass produced — see [`crate::screening::dual`]), radius evaluated
//!   with the previous primal value re-priced at the new lambda
//!   (Eq. 15-17); runs once in `begin_lambda`;
//! * *dynamic* — center the current iterate's dual point, radius from the
//!   current gap (Eq. 19-21); runs at every gap pass, so the sphere shrinks
//!   as the solver converges and screening keeps improving (Prop. 5-6).

use super::{apply_sphere, PrevSolution, ScreeningRule};
use crate::penalty::ActiveSet;
use crate::problem::{GapResult, Problem};

/// Which events the rule screens on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GapSafeVariant {
    /// Only at lambda boundaries, centered at the previous dual point.
    Sequential,
    /// Only along the iterations, centered at the current dual point.
    Dynamic,
    /// Both (the recommended rule; Alg. 1 + 2).
    Full,
}

/// Gap Safe sphere rule.
pub struct GapSafeRule {
    variant: GapSafeVariant,
    /// Cumulative counters for reports.
    pub screened_groups: usize,
    pub screened_feats: usize,
}

impl GapSafeRule {
    pub fn new(variant: GapSafeVariant) -> Self {
        GapSafeRule { variant, screened_groups: 0, screened_feats: 0 }
    }
}

impl ScreeningRule for GapSafeRule {
    fn name(&self) -> &'static str {
        match self.variant {
            GapSafeVariant::Sequential => "gap-seq",
            GapSafeVariant::Dynamic => "gap-dyn",
            GapSafeVariant::Full => "gap-full",
        }
    }

    fn begin_lambda(
        &mut self,
        prob: &Problem,
        lam: f64,
        _lam_max: f64,
        prev: Option<&PrevSolution>,
        active: &mut ActiveSet,
    ) {
        if self.variant == GapSafeVariant::Dynamic {
            return;
        }
        let Some(prev) = prev else { return };
        // Sequential sphere (Eq. 15-17): center theta-check_{t-1}, radius
        // r_{lambda_t}(beta_{t-1}, theta_{t-1}) evaluated at the *new* lambda.
        let primal_t = prev.loss + lam * prev.pen_value;
        let dual_t = prob.fit.dual(&prev.theta, lam);
        let gap_t = (primal_t - dual_t).max(0.0);
        // Radius through the curvature hook: global-gamma fits keep the
        // historical formula bit for bit; locally-bounded duals (Poisson)
        // get a bound centred at this sphere's own center, prev.theta.
        let radius = prob.fit.gap_safe_radius(gap_t, lam, &prev.theta);
        // The previous active set is not safe for lambda_t, so statistics are
        // computed over all groups.
        let full = ActiveSet::full(prob.pen.groups());
        let stats = prob.stats_for_center(&prev.theta, &full);
        let (kg, kf) = apply_sphere(prob, &stats, radius, &prev.theta, self.name(), "seq", active);
        self.screened_groups += kg;
        self.screened_feats += kf;
    }

    fn on_gap_pass(
        &mut self,
        prob: &Problem,
        _lam: f64,
        gap: &GapResult,
        active: &mut ActiveSet,
    ) {
        if self.variant == GapSafeVariant::Sequential {
            return;
        }
        // Dynamic sphere (Eq. 19-21): the solver already produced the
        // rescaled dual point and the Gap Safe radius in `gap`.
        let (kg, kf) =
            apply_sphere(prob, &gap.stats, gap.radius, &gap.theta, self.name(), "dyn", active);
        self.screened_groups += kg;
        self.screened_feats += kf;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datafit::{FitKind, Logistic, Multinomial, Poisson, Quadratic};
    use crate::linalg::sparse::Design;
    use crate::linalg::Mat;
    use crate::penalty::{GroupL2, Groups, L1};
    use crate::problem::Problem;
    use crate::util::prng::Prng;

    fn toy_problem(seed: u64, n: usize, p: usize) -> Problem {
        let mut rng = Prng::new(seed);
        let mut x = Mat::zeros(n, p);
        for v in x.as_mut_slice() {
            *v = rng.gaussian();
        }
        let y: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        Problem::new(Design::Dense(x), Box::new(Quadratic::from_vec(&y)), Box::new(L1::new(p)))
    }

    /// One problem per datafit family, all sharing one random design.
    fn all_fit_problems(seed: u64) -> Vec<Problem> {
        let mut rng = Prng::new(seed);
        let (n, p, q) = (18, 30, 3);
        let mut x = Mat::zeros(n, p);
        for v in x.as_mut_slice() {
            *v = rng.gaussian();
        }
        let yq: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let yb: Vec<f64> =
            (0..n).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect();
        let mut counts: Vec<f64> = (0..n).map(|_| rng.below(6) as f64).collect();
        counts[0] = counts[0].max(1.0);
        let mut ym = Mat::zeros(n, q);
        for i in 0..n {
            ym[(i, rng.below(q))] = 1.0;
        }
        vec![
            Problem::new(
                Design::Dense(x.clone()),
                Box::new(Quadratic::from_vec(&yq)),
                Box::new(L1::new(p)),
            ),
            Problem::new(
                Design::Dense(x.clone()),
                Box::new(Logistic::new(&yb)),
                Box::new(L1::new(p)),
            ),
            Problem::new(
                Design::Dense(x.clone()),
                Box::new(Multinomial::new(ym)),
                Box::new(GroupL2::new(Groups::singletons(p))),
            ),
            Problem::new(Design::Dense(x), Box::new(Poisson::new(&counts)), Box::new(L1::new(p))),
        ]
    }

    /// Omega^D(X^T theta) for the L1 / singleton-group penalties above:
    /// the max per-feature row norm of the correlation matrix.
    fn max_corr_row_norm(prob: &Problem, theta: &Mat) -> f64 {
        let mut m = 0.0_f64;
        for j in 0..prob.p() {
            let mut sq = 0.0;
            for c in 0..prob.q() {
                let d = prob.x.col_dot(j, theta.col(c));
                sq += d * d;
            }
            m = m.max(sq.sqrt());
        }
        m
    }

    #[test]
    fn rescaled_dual_points_are_feasible_with_nonnegative_gaps() {
        // For every datafit family: the rescaled theta of a gap pass is
        // dual feasible (unit dual-ball constraint + conjugate domain for
        // Poisson) and the reported duality gap is non-negative, at
        // arbitrary (non-optimal) iterates and several lambdas.
        for seed in 0..5u64 {
            for prob in all_fit_problems(seed) {
                let label = prob.fit.kind();
                let mut rng = Prng::new(seed ^ 0xD0D0);
                let mut beta = Mat::zeros(prob.p(), prob.q());
                for _ in 0..4 {
                    let j = rng.below(prob.p());
                    for c in 0..prob.q() {
                        beta[(j, c)] = 0.3 * rng.gaussian();
                    }
                }
                let z = prob.predict(&beta);
                let active = ActiveSet::full(prob.pen.groups());
                for ratio in [0.9, 0.5, 0.2] {
                    let lam = ratio * prob.lambda_max();
                    let res = prob.gap_pass(&beta, &z, lam, &active);
                    assert!(
                        res.gap >= 0.0,
                        "{label:?} ratio {ratio}: negative gap {}",
                        res.gap
                    );
                    assert!(res.radius.is_finite() && res.radius >= 0.0);
                    let dn = max_corr_row_norm(&prob, &res.theta);
                    assert!(
                        dn <= 1.0 + 1e-9,
                        "{label:?} ratio {ratio}: infeasible theta, Omega^D = {dn}"
                    );
                    if label == FitKind::Poisson {
                        // conjugate domain: v = y - lam * theta >= 0
                        let ys = prob.fit.targets();
                        for (ti, yi) in res.theta.as_slice().iter().zip(ys.as_slice()) {
                            let v = yi - lam * ti;
                            assert!(v >= -1e-12, "poisson conjugate arg {v} < 0");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn dynamic_screens_poisson_near_lambda_max() {
        let ds = crate::data::synth::poisson_like(20, 60, 3);
        let prob = crate::build_problem(ds, crate::Task::Poisson).unwrap();
        let lam = 0.95 * prob.lambda_max();
        let beta = Mat::zeros(60, 1);
        let z = prob.predict(&beta);
        let mut active = ActiveSet::full(prob.pen.groups());
        let res = prob.gap_pass(&beta, &z, lam, &active);
        assert!(res.radius.is_finite() && res.radius > 0.0);
        let mut rule = GapSafeRule::new(GapSafeVariant::Dynamic);
        rule.on_gap_pass(&prob, lam, &res, &mut active);
        assert!(
            active.n_active_feats() < 60,
            "poisson dynamic sphere screened nothing at 0.95 lambda_max"
        );
        assert!(active.n_active_feats() >= 1);
    }

    #[test]
    fn dynamic_screens_at_beta_zero_small_lambda_ratio() {
        // At beta = 0 with lambda just below lambda_max, the dynamic Gap Safe
        // sphere is tight enough to kill most features immediately.
        let prob = toy_problem(1, 20, 60);
        let lam = 0.95 * prob.lambda_max();
        let beta = Mat::zeros(60, 1);
        let z = prob.predict(&beta);
        let mut active = ActiveSet::full(prob.pen.groups());
        let res = prob.gap_pass(&beta, &z, lam, &active);
        let mut rule = GapSafeRule::new(GapSafeVariant::Dynamic);
        rule.on_gap_pass(&prob, lam, &res, &mut active);
        assert!(
            active.n_active_feats() < 60,
            "expected some screening at lambda close to lambda_max"
        );
    }

    #[test]
    fn sequential_noop_without_prev() {
        let prob = toy_problem(2, 10, 20);
        let mut active = ActiveSet::full(prob.pen.groups());
        let mut rule = GapSafeRule::new(GapSafeVariant::Sequential);
        rule.begin_lambda(&prob, 0.5 * prob.lambda_max(), prob.lambda_max(), None, &mut active);
        assert_eq!(active.n_active_feats(), 20);
    }

    #[test]
    fn sequential_screens_with_exact_prev() {
        // Previous point = exact solution at lambda_max (beta = 0, theta =
        // rho/lambda_max): sequential screening at lambda slightly smaller
        // must keep at least the argmax feature and kill far-away ones.
        let prob = toy_problem(3, 15, 40);
        let lmax = prob.lambda_max();
        let beta = Mat::zeros(40, 1);
        let z = prob.predict(&beta);
        let active_full = ActiveSet::full(prob.pen.groups());
        let g = prob.gap_pass(&beta, &z, lmax, &active_full);
        let prev = PrevSolution {
            lam: lmax,
            beta: beta.clone(),
            z: z.clone(),
            theta: g.theta.clone(),
            loss: prob.fit.loss(&z),
            pen_value: 0.0,
            active: active_full.clone(),
        };
        let lam = 0.97 * lmax;
        let mut active = ActiveSet::full(prob.pen.groups());
        let mut rule = GapSafeRule::new(GapSafeVariant::Sequential);
        rule.begin_lambda(&prob, lam, lmax, Some(&prev), &mut active);
        assert!(active.n_active_feats() < 40, "sequential rule screened nothing");
        assert!(active.n_active_feats() >= 1);
    }
}
