//! The paper's contribution: Gap Safe spheres (Thm. 2) applied statically,
//! sequentially (Eq. 15-17) and dynamically (Eq. 19-21).
//!
//! Both variants use the radius of Thm. 2,
//! `r = sqrt(2 * gap) / (lambda * sqrt(gamma))` (see the
//! [module docs](crate::screening) for the full sphere math):
//!
//! * *sequential* — center `theta_{t-1}` (the dual point kept from the
//!   previous path point; with the default `dual = best` strategy this is
//!   the *best* dual point that lambda ever saw, not whatever the last
//!   pass produced — see [`crate::screening::dual`]), radius evaluated
//!   with the previous primal value re-priced at the new lambda
//!   (Eq. 15-17); runs once in `begin_lambda`;
//! * *dynamic* — center the current iterate's dual point, radius from the
//!   current gap (Eq. 19-21); runs at every gap pass, so the sphere shrinks
//!   as the solver converges and screening keeps improving (Prop. 5-6).

use super::{apply_sphere, PrevSolution, ScreeningRule};
use crate::penalty::ActiveSet;
use crate::problem::{GapResult, Problem};

/// Which events the rule screens on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GapSafeVariant {
    /// Only at lambda boundaries, centered at the previous dual point.
    Sequential,
    /// Only along the iterations, centered at the current dual point.
    Dynamic,
    /// Both (the recommended rule; Alg. 1 + 2).
    Full,
}

/// Gap Safe sphere rule.
pub struct GapSafeRule {
    variant: GapSafeVariant,
    /// Cumulative counters for reports.
    pub screened_groups: usize,
    pub screened_feats: usize,
}

impl GapSafeRule {
    pub fn new(variant: GapSafeVariant) -> Self {
        GapSafeRule { variant, screened_groups: 0, screened_feats: 0 }
    }
}

impl ScreeningRule for GapSafeRule {
    fn name(&self) -> &'static str {
        match self.variant {
            GapSafeVariant::Sequential => "gap-seq",
            GapSafeVariant::Dynamic => "gap-dyn",
            GapSafeVariant::Full => "gap-full",
        }
    }

    fn begin_lambda(
        &mut self,
        prob: &Problem,
        lam: f64,
        _lam_max: f64,
        prev: Option<&PrevSolution>,
        active: &mut ActiveSet,
    ) {
        if self.variant == GapSafeVariant::Dynamic {
            return;
        }
        let Some(prev) = prev else { return };
        // Sequential sphere (Eq. 15-17): center theta-check_{t-1}, radius
        // r_{lambda_t}(beta_{t-1}, theta_{t-1}) evaluated at the *new* lambda.
        let primal_t = prev.loss + lam * prev.pen_value;
        let dual_t = prob.fit.dual(&prev.theta, lam);
        let gap_t = (primal_t - dual_t).max(0.0);
        let radius = (2.0 * gap_t / prob.fit.gamma()).sqrt() / lam;
        // The previous active set is not safe for lambda_t, so statistics are
        // computed over all groups.
        let full = ActiveSet::full(prob.pen.groups());
        let stats = prob.stats_for_center(&prev.theta, &full);
        let (kg, kf) = apply_sphere(prob, &stats, radius, active);
        self.screened_groups += kg;
        self.screened_feats += kf;
    }

    fn on_gap_pass(
        &mut self,
        prob: &Problem,
        _lam: f64,
        gap: &GapResult,
        active: &mut ActiveSet,
    ) {
        if self.variant == GapSafeVariant::Sequential {
            return;
        }
        // Dynamic sphere (Eq. 19-21): the solver already produced the
        // rescaled dual point and the Gap Safe radius in `gap`.
        let (kg, kf) = apply_sphere(prob, &gap.stats, gap.radius, active);
        self.screened_groups += kg;
        self.screened_feats += kf;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datafit::Quadratic;
    use crate::linalg::sparse::Design;
    use crate::linalg::Mat;
    use crate::penalty::L1;
    use crate::problem::Problem;
    use crate::util::prng::Prng;

    fn toy_problem(seed: u64, n: usize, p: usize) -> Problem {
        let mut rng = Prng::new(seed);
        let mut x = Mat::zeros(n, p);
        for v in x.as_mut_slice() {
            *v = rng.gaussian();
        }
        let y: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        Problem::new(Design::Dense(x), Box::new(Quadratic::from_vec(&y)), Box::new(L1::new(p)))
    }

    #[test]
    fn dynamic_screens_at_beta_zero_small_lambda_ratio() {
        // At beta = 0 with lambda just below lambda_max, the dynamic Gap Safe
        // sphere is tight enough to kill most features immediately.
        let prob = toy_problem(1, 20, 60);
        let lam = 0.95 * prob.lambda_max();
        let beta = Mat::zeros(60, 1);
        let z = prob.predict(&beta);
        let mut active = ActiveSet::full(prob.pen.groups());
        let res = prob.gap_pass(&beta, &z, lam, &active);
        let mut rule = GapSafeRule::new(GapSafeVariant::Dynamic);
        rule.on_gap_pass(&prob, lam, &res, &mut active);
        assert!(
            active.n_active_feats() < 60,
            "expected some screening at lambda close to lambda_max"
        );
    }

    #[test]
    fn sequential_noop_without_prev() {
        let prob = toy_problem(2, 10, 20);
        let mut active = ActiveSet::full(prob.pen.groups());
        let mut rule = GapSafeRule::new(GapSafeVariant::Sequential);
        rule.begin_lambda(&prob, 0.5 * prob.lambda_max(), prob.lambda_max(), None, &mut active);
        assert_eq!(active.n_active_feats(), 20);
    }

    #[test]
    fn sequential_screens_with_exact_prev() {
        // Previous point = exact solution at lambda_max (beta = 0, theta =
        // rho/lambda_max): sequential screening at lambda slightly smaller
        // must keep at least the argmax feature and kill far-away ones.
        let prob = toy_problem(3, 15, 40);
        let lmax = prob.lambda_max();
        let beta = Mat::zeros(40, 1);
        let z = prob.predict(&beta);
        let active_full = ActiveSet::full(prob.pen.groups());
        let g = prob.gap_pass(&beta, &z, lmax, &active_full);
        let prev = PrevSolution {
            lam: lmax,
            beta: beta.clone(),
            z: z.clone(),
            theta: g.theta.clone(),
            loss: prob.fit.loss(&z),
            pen_value: 0.0,
            active: active_full.clone(),
        };
        let lam = 0.97 * lmax;
        let mut active = ActiveSet::full(prob.pen.groups());
        let mut rule = GapSafeRule::new(GapSafeVariant::Sequential);
        rule.begin_lambda(&prob, lam, lmax, Some(&prev), &mut active);
        assert!(active.n_active_feats() < 40, "sequential rule screened nothing");
        assert!(active.n_active_feats() >= 1);
    }
}
