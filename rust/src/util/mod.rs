//! Substrate utilities: PRNG, mini-JSON, timing, CSV, and the lightweight
//! property-test harness (the offline registry has no rand/serde/proptest).

pub mod json;
pub mod prng;
pub mod sync;

use std::time::Instant;

/// A simple stopwatch for coordinator metrics.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    /// Elapsed seconds since construction.
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Write rows of (stringified) cells as CSV with a header line.
pub fn write_csv(
    path: &std::path::Path,
    header: &[&str],
    rows: &[Vec<String>],
) -> std::io::Result<()> {
    use std::io::Write;
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "{}", header.join(","))?;
    for row in rows {
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(())
}

/// Format seconds with adaptive precision for table output.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.2}s")
    }
}

/// Property-test driver: runs `body` for `cases` seeded cases and reports the
/// failing seed, mimicking proptest's shrink-free core loop. Each case gets
/// an independent `Prng` so failures reproduce from the printed seed.
pub fn check_property<F: FnMut(&mut prng::Prng) -> Result<(), String>>(
    name: &str,
    cases: u64,
    mut body: F,
) {
    for case in 0..cases {
        let seed = 0x5EED_0000u64 ^ (case.wrapping_mul(0x9E37_79B9));
        let mut rng = prng::Prng::new(seed);
        if let Err(msg) = body(&mut rng) {
            panic!("property '{name}' failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        let a = sw.secs();
        let b = sw.secs();
        assert!(b >= a);
    }

    #[test]
    fn fmt_secs_ranges() {
        assert!(fmt_secs(1e-5).ends_with("us"));
        assert!(fmt_secs(0.01).ends_with("ms"));
        assert!(fmt_secs(2.5).ends_with('s'));
    }

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("gapsafe_csv_test");
        let path = dir.join("t.csv");
        write_csv(&path, &["a", "b"], &[vec!["1".into(), "2".into()]]).unwrap();
        let s = std::fs::read_to_string(&path).unwrap();
        assert_eq!(s, "a,b\n1,2\n");
    }

    #[test]
    #[should_panic(expected = "property 'boom' failed")]
    fn property_reports_failure() {
        check_property("boom", 5, |rng| {
            if rng.uniform() >= 0.0 {
                Err("always".into())
            } else {
                Ok(())
            }
        });
    }
}
