//! Minimal JSON parser + writer (the offline registry has no serde).
//!
//! Supports the full JSON grammar, including `\u` surrogate pairs beyond
//! the BMP; ample for the artifact manifest, the serving endpoints
//! ([`crate::serve`]) and the result files this crate exchanges with the
//! Python compile path.
//!
//! # Round-trip contract
//!
//! `parse(v.to_string()) == v` for every value the writer can emit, and
//! finite [`Json::Num`] survives **bitwise** (the writer uses Rust's
//! shortest-round-trip `f64` formatting and preserves `-0.0`). JSON has no
//! `NaN`/`inf` literals, so non-finite numbers serialize as `null` — the
//! one lossy case, by construction. The serving layer relies on the
//! bitwise guarantee to hand coefficients over HTTP without perturbing
//! them.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The value as a usize — `None` for negative, non-integral or
    /// out-of-range numbers (API inputs must not be silently coerced).
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            // strict <: usize::MAX as f64 rounds up to 2^64, which would
            // admit an out-of-range value that saturates on cast
            if x >= 0.0 && x.fract() == 0.0 && x < usize::MAX as f64 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Build an object from `(key, value)` pairs (endpoint ergonomics).
    pub fn obj<K: Into<String>, I: IntoIterator<Item = (K, Json)>>(pairs: I) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.into(), v)).collect())
    }

    /// An array of numbers.
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect_byte(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect_byte(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let e = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            if (0xD800..0xDC00).contains(&cp) {
                                // High surrogate: pair it with a following
                                // \uDC00-\uDFFF low surrogate (non-BMP code
                                // points, e.g. emoji); unpaired surrogates
                                // become U+FFFD.
                                let paired = self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u');
                                if paired {
                                    let save = self.i;
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    if (0xDC00..0xE000).contains(&lo) {
                                        let c = 0x10000
                                            + ((cp - 0xD800) << 10)
                                            + (lo - 0xDC00);
                                        out.push(char::from_u32(c).unwrap_or('\u{fffd}'));
                                    } else {
                                        // not a low surrogate: re-parse the
                                        // escape on the next loop pass
                                        self.i = save;
                                        out.push('\u{fffd}');
                                    }
                                } else {
                                    out.push('\u{fffd}');
                                }
                            } else if (0xDC00..0xE000).contains(&cp) {
                                out.push('\u{fffd}'); // lone low surrogate
                            } else {
                                out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            }
                        }
                        _ => return Err(format!("bad escape at {}", self.i)),
                    }
                }
                Some(c) => {
                    // Copy a full UTF-8 sequence.
                    let len = match c {
                        0x00..=0x7F => 1,
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let chunk = std::str::from_utf8(&self.b[self.i..self.i + len])
                        .map_err(|_| "bad utf8")?;
                    out.push_str(chunk);
                    self.i += len;
                }
            }
        }
    }

    /// Four hex digits of a `\u` escape.
    fn hex4(&mut self) -> Result<u32, String> {
        if self.i + 4 > self.b.len() {
            return Err("bad \\u".into());
        }
        let hex =
            std::str::from_utf8(&self.b[self.i..self.i + 4]).map_err(|_| "bad \\u")?;
        let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u")?;
        self.i += 4;
        Ok(cp)
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect_byte(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect_byte(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

impl fmt::Display for Json {
    /// Compact JSON serialisation (used for result files).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if !x.is_finite() {
                    // JSON has no NaN/inf literals.
                    write!(f, "null")
                } else if *x == 0.0 && x.is_sign_negative() {
                    // `as i64` would drop the sign bit; "-0" parses back to
                    // -0.0, keeping Num round-trips bitwise.
                    write!(f, "-0")
                } else if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    // Rust's shortest representation re-parses to the same
                    // bits, so finite numbers round-trip exactly.
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let doc = r#"{"version": 1, "artifacts": [{"name": "lasso_small", "n": 16,
            "p": 40, "dtype": "f64", "inputs": ["X", "y"], "ok": true}]}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("version").unwrap().as_f64(), Some(1.0));
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("name").unwrap().as_str(), Some("lasso_small"));
        assert_eq!(arts[0].get("p").unwrap().as_usize(), Some(40));
        assert_eq!(arts[0].get("ok"), Some(&Json::Bool(true)));
    }

    #[test]
    fn roundtrip() {
        let doc = r#"{"a":[1,2.5,-3e2],"b":"x\ny","c":null,"d":false}"#;
        let v = Json::parse(doc).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café ✓ ok""#).unwrap();
        assert_eq!(v.as_str(), Some("café ✓ ok"));
    }

    #[test]
    fn nested() {
        let v = Json::parse(r#"[[[1],[2]],{"k":{"kk":[true]}}]"#).unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a.len(), 2);
    }

    #[test]
    fn surrogate_pairs_beyond_bmp() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("\u{1F600}"));
        // lone surrogates degrade to U+FFFD instead of erroring
        let lone = Json::parse(r#""a\ud83db""#).unwrap();
        assert_eq!(lone.as_str(), Some("a\u{fffd}b"));
        let lo = Json::parse(r#""\ude00""#).unwrap();
        assert_eq!(lo.as_str(), Some("\u{fffd}"));
        // raw (unescaped) non-BMP round-trips through the writer
        let raw = Json::Str("\u{1F600}".into());
        assert_eq!(Json::parse(&raw.to_string()).unwrap(), raw);
    }

    #[test]
    fn numbers_roundtrip_bitwise() {
        for x in [
            0.0,
            -0.0,
            1.0,
            -3.0,
            2.5,
            -1e-300,
            1e300,
            1e15,
            -1e15,
            0.1 + 0.2,
            f64::MIN_POSITIVE,
            std::f64::consts::PI,
        ] {
            let s = Json::Num(x).to_string();
            let back = Json::parse(&s).unwrap();
            let y = back.as_f64().unwrap();
            assert_eq!(x.to_bits(), y.to_bits(), "{x:?} -> {s} -> {y:?}");
        }
        // non-finite numbers become null (the only lossy case)
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn as_usize_rejects_negative_and_fractional() {
        assert_eq!(Json::Num(7.0).as_usize(), Some(7));
        assert_eq!(Json::Num(0.0).as_usize(), Some(0));
        assert_eq!(Json::Num(-1.0).as_usize(), None);
        assert_eq!(Json::Num(7.9).as_usize(), None);
        assert_eq!(Json::Num(f64::NAN).as_usize(), None);
        assert_eq!(Json::Num(1e300).as_usize(), None);
        assert_eq!(Json::Str("7".into()).as_usize(), None);
    }

    #[test]
    fn helpers_and_builders() {
        let v = Json::obj([("ok", Json::Bool(true)), ("xs", Json::arr_f64(&[1.0, 2.5]))]);
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(v.get("xs").unwrap().as_arr().unwrap().len(), 2);
        assert!(v.as_obj().is_some());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    /// Random finite JSON value for the round-trip property.
    fn random_json(rng: &mut crate::util::prng::Prng, depth: usize) -> Json {
        let kinds = if depth >= 3 { 4 } else { 6 };
        match rng.below(kinds) {
            0 => Json::Null,
            1 => Json::Bool(rng.bernoulli(0.5)),
            2 => {
                // mix of magnitudes; always finite
                let exp = rng.uniform_in(-300.0, 300.0);
                let x = rng.gaussian() * 10f64.powf(exp);
                Json::Num(if x.is_finite() { x } else { 0.0 })
            }
            3 => {
                let corpus = [
                    "", "plain", "esc\"ape\\", "tab\tnl\n", "café ✓", "\u{1F600}🎉",
                    "ctrl\u{1}\u{1f}", "/slash/",
                ];
                Json::Str(corpus[rng.below(corpus.len())].to_string())
            }
            4 => {
                let n = rng.below(4);
                Json::Arr((0..n).map(|_| random_json(rng, depth + 1)).collect())
            }
            _ => {
                let n = rng.below(4);
                let mut m = BTreeMap::new();
                for i in 0..n {
                    m.insert(format!("k{i}"), random_json(rng, depth + 1));
                }
                Json::Obj(m)
            }
        }
    }

    #[test]
    fn serialize_parse_roundtrip_property() {
        // parse ∘ serialize = id, and serialization is idempotent, over a
        // few hundred random documents (plus the hand-written corpus).
        crate::util::check_property("json_roundtrip", 300, |rng| {
            let v = random_json(rng, 0);
            let s = v.to_string();
            let back = Json::parse(&s).map_err(|e| format!("unparseable {s:?}: {e}"))?;
            if back != v {
                return Err(format!("value changed through {s:?}"));
            }
            if back.to_string() != s {
                return Err(format!("serialization not idempotent on {s:?}"));
            }
            Ok(())
        });
        for doc in [
            r#"{"version": 1, "artifacts": [{"name": "lasso_small", "ok": true}]}"#,
            r#"{"a":[1,2.5,-3e2],"b":"x\ny","c":null,"d":false}"#,
            r#"[[[1],[2]],{"k":{"kk":[true]}}]"#,
            r#""café ✓ ok""#,
        ] {
            let v = Json::parse(doc).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v, "corpus doc {doc}");
        }
    }
}
