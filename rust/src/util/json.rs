//! Minimal JSON parser + writer (the offline registry has no serde).
//!
//! Supports the full JSON grammar except `\u` surrogate pairs beyond the BMP;
//! ample for the artifact manifest and result files this crate exchanges
//! with the Python compile path.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let e = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("bad \\u".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u")?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape at {}", self.i)),
                    }
                }
                Some(c) => {
                    // Copy a full UTF-8 sequence.
                    let len = match c {
                        0x00..=0x7F => 1,
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let chunk = std::str::from_utf8(&self.b[self.i..self.i + len])
                        .map_err(|_| "bad utf8")?;
                    out.push_str(chunk);
                    self.i += len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

impl fmt::Display for Json {
    /// Compact JSON serialisation (used for result files).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let doc = r#"{"version": 1, "artifacts": [{"name": "lasso_small", "n": 16,
            "p": 40, "dtype": "f64", "inputs": ["X", "y"], "ok": true}]}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("version").unwrap().as_f64(), Some(1.0));
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("name").unwrap().as_str(), Some("lasso_small"));
        assert_eq!(arts[0].get("p").unwrap().as_usize(), Some(40));
        assert_eq!(arts[0].get("ok"), Some(&Json::Bool(true)));
    }

    #[test]
    fn roundtrip() {
        let doc = r#"{"a":[1,2.5,-3e2],"b":"x\ny","c":null,"d":false}"#;
        let v = Json::parse(doc).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café ✓ ok""#).unwrap();
        assert_eq!(v.as_str(), Some("café ✓ ok"));
    }

    #[test]
    fn nested() {
        let v = Json::parse(r#"[[[1],[2]],{"k":{"kk":[true]}}]"#).unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a.len(), 2);
    }
}
