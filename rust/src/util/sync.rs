//! Poison-recovering synchronization helpers, shared by every resident
//! or pooled component (`serve`, the parallel solver pool, trace sinks).
//!
//! A poisoned mutex means some thread panicked while holding the guard —
//! it says nothing about the guarded data once every critical section
//! leaves its structure consistent at each unwind point. Components that
//! must outlive a single worker panic (the HTTP server, the scoped solver
//! pool joining its results) recover the guard instead of converting one
//! panic into a cascade of `lock().unwrap()` panics; the panic itself
//! still surfaces where it belongs (scope join, worker respawn, 5xx).

use std::sync::{Condvar, Mutex, MutexGuard, PoisonError, WaitTimeoutResult};
use std::time::Duration;

/// Lock a mutex, recovering from poisoning instead of panicking.
///
/// Recovery is sound wherever each critical section leaves the guarded
/// data structurally consistent at every step a panic can interrupt
/// (inserts/removes complete before user code that could panic runs).
/// Every call site in this crate maintains that discipline; the
/// `panic-reachability` audit lint keeps new call sites honest.
pub fn lock_ok<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// [`Condvar::wait`] with the same poison recovery as [`lock_ok`].
pub fn wait_ok<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(g).unwrap_or_else(PoisonError::into_inner)
}

/// [`Condvar::wait_timeout`] with the same poison recovery as [`lock_ok`].
pub fn wait_timeout_ok<'a, T>(
    cv: &Condvar,
    g: MutexGuard<'a, T>,
    dur: Duration,
) -> (MutexGuard<'a, T>, WaitTimeoutResult) {
    cv.wait_timeout(g, dur).unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn lock_ok_recovers_from_poison() {
        let m = Arc::new(Mutex::new(7usize));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(m.is_poisoned());
        assert_eq!(*lock_ok(&m), 7);
    }

    #[test]
    fn wait_timeout_ok_times_out() {
        let m = Mutex::new(());
        let cv = Condvar::new();
        let g = lock_ok(&m);
        let (_g, res) = wait_timeout_ok(&cv, g, Duration::from_millis(1));
        assert!(res.timed_out());
    }
}
