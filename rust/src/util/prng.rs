//! Deterministic PRNG used by synthetic data generators and property tests.
//!
//! The offline crate registry has no `rand`, so we ship SplitMix64 (Steele,
//! Lea & Flood 2014) — a tiny, high-quality 64-bit generator — plus
//! Box–Muller Gaussians. Every generator in `data::synth` is seeded, so all
//! experiments are exactly reproducible.

/// SplitMix64 stream with cached second Box–Muller deviate.
#[derive(Debug, Clone)]
pub struct Prng {
    state: u64,
    gauss_cache: Option<f64>,
}

impl Prng {
    /// Create a generator from a seed (any value, including 0, is fine).
    pub fn new(seed: u64) -> Self {
        Prng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15), gauss_cache: None }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 top bits -> [0,1) with full double resolution.
        (self.next_u64() >> 11) as f64 * (1.0 / 9007199254740992.0)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Rejection-free modulo is fine for our n << 2^64 use cases.
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(g) = self.gauss_cache.take() {
            return g;
        }
        // Avoid log(0).
        let u1 = loop {
            let u = self.uniform();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.gauss_cache = Some(r * s);
        r * c
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// `k` distinct indices from [0, n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Prng::new(7);
        let mut s = 0.0;
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            s += u;
        }
        let mean = s / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Prng::new(11);
        let n = 20_000;
        let (mut m1, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.gaussian();
            m1 += g;
            m2 += g * g;
        }
        m1 /= n as f64;
        m2 /= n as f64;
        assert!(m1.abs() < 0.03, "mean={m1}");
        assert!((m2 - 1.0).abs() < 0.05, "var={m2}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Prng::new(3);
        let idx = r.sample_indices(50, 20);
        assert_eq!(idx.len(), 20);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
        assert!(sorted.iter().all(|&i| i < 50));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Prng::new(9);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut s = xs.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }
}
