//! # gapsafe — Gap Safe screening rules for sparsity enforcing penalties
//!
//! A production-grade reproduction of Ndiaye, Fercoq, Gramfort & Salmon,
//! *"Gap Safe screening rules for sparsity enforcing penalties"* (2016/17),
//! built as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the pathwise sparse-GLM solver framework:
//!   block coordinate descent ([`solver`]), the complete screening-rule zoo
//!   ([`screening`]) with Gap Safe static / sequential / dynamic rules as a
//!   first-class feature, active / strong warm starts ([`solver::path`]),
//!   and an experiment coordinator ([`coordinator`]) regenerating every
//!   figure of the paper's evaluation.
//! * **Layer 2** — JAX duality-gap graphs (`python/compile/model.py`)
//!   AOT-lowered to HLO text and executed from Rust via PJRT ([`runtime`]).
//! * **Layer 1** — Pallas column-tiled screening kernels
//!   (`python/compile/kernels/screen.py`).
//!
//! The solver stack is parallel end to end on a std-only scoped-thread
//! pool ([`solver::parallel`]): chunked lambda grids in
//! [`solver::path::solve_path`] (`PathConfig::threads`), concurrent CV
//! folds and tau candidates ([`coordinator::cv`]), fanned-out screening
//! sweeps (`Problem::set_screen_threads`), and batch serving of many path
//! requests ([`coordinator::BatchRunner`]). `threads = 1` always takes the
//! exact serial path.
//!
//! As screening shrinks the problem, the CD solver *compacts* it: the
//! surviving columns are physically repacked into a contiguous working
//! matrix ([`linalg::compact::CompactDesign`]) so epochs and gap passes
//! stop scanning the dead 90%+ of the design. Compaction is
//! bitwise-transparent (`PathConfig::compact`, on by default; see the
//! "Working-set compaction" section of the [`screening`] docs).
//!
//! Every gap pass runs through a dual-point engine
//! ([`screening::dual`]): the solver keeps the best dual objective seen
//! per lambda (`PathConfig::dual`, default `best`), so the reported gap
//! — and the Gap Safe radius built from it — is monotonically
//! non-increasing across passes instead of oscillating with the raw
//! residual rescaling (`rescale` restores the historical output bit for
//! bit; `refine` adds a convex-combination line search).
//!
//! All numerical hot loops — dense dots/axpys, the register-tiled
//! `X^T v` correlation sweep, the CSC gather kernels — run through a
//! runtime-dispatched SIMD engine ([`linalg::kernels`]): the best
//! supported backend (AVX2 via stable `std::arch`, or portable scalar)
//! is detected once at startup, overridable with
//! `GAPSAFE_KERNEL=scalar|avx2|auto` or the CLI `--kernel` flag. Every
//! backend is **bitwise identical** by contract, so the backend choice
//! can never change a solver trajectory, a screening decision, or a
//! served prediction — `rust/tests/kernel_parity.rs` pins whole
//! `solve_path` runs bit-for-bit across backends.
//!
//! On top of it sits a resident model-serving subsystem ([`serve`]):
//! `gapsafe serve` runs a std-only HTTP server whose model registry keeps
//! fitted paths alive between requests, answering repeat fits from cache
//! and nearby-lambda fits through warm starts seeded by the closest
//! cached solution (`POST /v1/fit`, `GET /v1/jobs/{id}`,
//! `POST /v1/predict`, `GET /healthz`, `GET /metrics`).
//!
//! The contracts above are enforced at the source level by a built-in
//! static-analysis pass ([`analysis`], `gapsafe audit`): six named lints
//! (float-determinism, simd-containment, trace-transparency,
//! unsafe-hygiene, determinism, serve-no-panic) walk the token stream of
//! every file under `rust/src/` and gate CI — see `docs/ANALYSIS.md`.
//!
//! Quick start:
//!
//! ```no_run
//! use gapsafe::prelude::*;
//!
//! let ds = gapsafe::data::synth::leukemia_like_scaled(40, 200, 0, false);
//! let prob = build_problem(ds, Task::Lasso).unwrap();
//! let cfg = PathConfig { threads: 4, ..PathConfig::default() };
//! let res = solve_path(&prob, &cfg);
//! println!("solved {} lambdas", res.points.len());
//! ```

// Numeric-kernel code indexes matrices heavily and threads wide argument
// lists through Alg. 1/2; these pedantic lints fight the domain style.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]

pub mod analysis;
pub mod coordinator;
pub mod data;
pub mod datafit;
pub mod linalg;
pub mod obs;
pub mod penalty;
pub mod problem;
pub mod runtime;
pub mod screening;
pub mod serve;
pub mod solver;
pub mod util;

use data::Dataset;
use datafit::{Logistic, Multinomial, Poisson, Quadratic};
use penalty::{GroupL2, Groups, SparseGroup, L1};
use problem::Problem;

/// The estimator families of Table 1.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Task {
    /// l1 least squares (Sec. 4.1).
    Lasso,
    /// l1/l2 with contiguous groups of the dataset's `group_size` (Sec. 4.2).
    GroupLasso,
    /// Sparse-Group Lasso with trade-off tau (Sec. 4.3).
    SparseGroupLasso { tau: f64 },
    /// l1 binary logistic regression (Sec. 4.4).
    Logreg,
    /// l1/l2 multi-task regression (Sec. 4.5).
    MultiTask,
    /// l1/l2 multinomial regression (Sec. 4.6).
    Multinomial,
    /// l1 Poisson regression (KL data fit) with the locally-bounded dual
    /// screening variant of Dantas, Soubies & Fevotte (2021).
    Poisson,
}

impl Task {
    pub fn parse(s: &str) -> Result<Task, String> {
        match s {
            "lasso" => Ok(Task::Lasso),
            "group-lasso" | "grouplasso" => Ok(Task::GroupLasso),
            "logreg" | "logistic" => Ok(Task::Logreg),
            "multitask" | "multi-task" => Ok(Task::MultiTask),
            "multinomial" => Ok(Task::Multinomial),
            "poisson" => Ok(Task::Poisson),
            s if s.starts_with("sgl") => {
                let tau = s
                    .strip_prefix("sgl:")
                    .map(|t| t.parse::<f64>().map_err(|e| e.to_string()))
                    .unwrap_or(Ok(0.4))?;
                Ok(Task::SparseGroupLasso { tau })
            }
            other => Err(format!(
                "unknown task '{other}' (lasso | group-lasso | sgl[:tau] | logreg | multitask | multinomial | poisson)"
            )),
        }
    }
}

/// Assemble a [`Problem`] from a dataset and a task.
pub fn build_problem(ds: Dataset, task: Task) -> Result<Problem, String> {
    let p = ds.p();
    match task {
        Task::Lasso => Ok(Problem::new(
            ds.x,
            Box::new(Quadratic::new(ds.y)),
            Box::new(L1::new(p)),
        )),
        Task::GroupLasso => {
            let gs = ds.group_size.ok_or("dataset has no group structure")?;
            Ok(Problem::new(
                ds.x,
                Box::new(Quadratic::new(ds.y)),
                Box::new(GroupL2::new(Groups::contiguous(p, gs))),
            ))
        }
        Task::SparseGroupLasso { tau } => {
            let gs = ds.group_size.ok_or("dataset has no group structure")?;
            Ok(Problem::new(
                ds.x,
                Box::new(Quadratic::new(ds.y)),
                Box::new(SparseGroup::with_unit_weights(Groups::contiguous(p, gs), tau)),
            ))
        }
        Task::Logreg => {
            if ds.q() != 1 {
                return Err("logreg needs scalar labels".into());
            }
            let y: Vec<f64> = ds.y.as_slice().to_vec();
            Ok(Problem::new(ds.x, Box::new(Logistic::new(&y)), Box::new(L1::new(p))))
        }
        Task::MultiTask => Ok(Problem::new(
            ds.x,
            Box::new(Quadratic::new(ds.y)),
            Box::new(GroupL2::new(Groups::singletons(p))),
        )),
        Task::Multinomial => Ok(Problem::new(
            ds.x,
            Box::new(Multinomial::new(ds.y)),
            Box::new(GroupL2::new(Groups::singletons(p))),
        )),
        Task::Poisson => {
            if ds.q() != 1 {
                return Err("poisson needs scalar counts".into());
            }
            let y: Vec<f64> = ds.y.as_slice().to_vec();
            // Validate here (Err, not panic) so serve can answer 400.
            if y.iter().any(|v| !v.is_finite() || *v < 0.0) {
                return Err("poisson counts must be finite and >= 0".into());
            }
            Ok(Problem::new(ds.x, Box::new(Poisson::new(&y)), Box::new(L1::new(p))))
        }
    }
}

/// Convenience re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::build_problem;
    pub use crate::coordinator::cv::{kfold_cv, CvConfig, CvResult};
    pub use crate::coordinator::{report, BatchRunner};
    pub use crate::data::{synth, Dataset};
    pub use crate::penalty::ActiveSet;
    pub use crate::problem::Problem;
    pub use crate::screening::Rule;
    pub use crate::serve::registry::{ModelKey, Registry};
    pub use crate::serve::{ServeConfig, Server};
    pub use crate::solver::parallel::effective_threads;
    pub use crate::solver::path::{solve_path, PathConfig, WarmStart};
    pub use crate::solver::{solve_fixed_lambda, SolveOptions};
    pub use crate::Task;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_parse() {
        assert_eq!(Task::parse("lasso").unwrap(), Task::Lasso);
        assert_eq!(Task::parse("sgl:0.25").unwrap(), Task::SparseGroupLasso { tau: 0.25 });
        assert_eq!(Task::parse("poisson").unwrap(), Task::Poisson);
        assert!(Task::parse("nope").is_err());
    }

    #[test]
    fn build_problem_poisson_validates_counts() {
        let ds = data::synth::poisson_like(12, 18, 3);
        assert!(build_problem(ds, Task::Poisson).is_ok());
        let mut bad = data::synth::poisson_like(12, 18, 3);
        bad.y[(0, 0)] = -1.0;
        let err = build_problem(bad, Task::Poisson).unwrap_err();
        assert!(err.contains("counts"), "unhelpful error: {err}");
        let mut nan = data::synth::poisson_like(12, 18, 3);
        nan.y[(0, 0)] = f64::NAN;
        assert!(build_problem(nan, Task::Poisson).is_err());
    }

    #[test]
    fn build_problem_all_tasks() {
        let mut ds = data::synth::leukemia_like_scaled(10, 12, 1, false);
        ds.group_size = Some(3);
        assert!(build_problem(ds.clone(), Task::Lasso).is_ok());
        assert!(build_problem(ds.clone(), Task::GroupLasso).is_ok());
        assert!(build_problem(ds.clone(), Task::SparseGroupLasso { tau: 0.4 }).is_ok());
        assert!(build_problem(ds.clone(), Task::MultiTask).is_ok());
        let dsb = data::synth::leukemia_like_scaled(10, 12, 1, true);
        assert!(build_problem(dsb, Task::Logreg).is_ok());
        let (dsm, _) = data::synth::multinomial_like(10, 8, 3, 2);
        assert!(build_problem(dsm, Task::Multinomial).is_ok());
    }
}
