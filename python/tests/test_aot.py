"""AOT path: HLO text emission is deterministic, parseable metadata, and the
small-registry artifacts can be produced end-to-end into a tmp dir."""

import json
import os
import subprocess
import sys

import jax

jax.config.update("jax_enable_x64", True)

from compile import aot, model


def test_lower_small_entries_deterministic():
    for name, task, n, p, q, gs in aot.REGISTRY:
        if not name.endswith("_small"):
            continue
        t1 = aot.lower_entry(task, n, p, q, gs)
        t2 = aot.lower_entry(task, n, p, q, gs)
        assert t1 == t2, f"non-deterministic lowering for {name}"
        assert "ENTRY" in t1 and "HloModule" in t1


def test_hlo_text_mentions_f64():
    t = aot.lower_entry("lasso", 8, 12, 1, 1)
    assert "f64" in t


def test_registry_covers_all_tasks_and_paper_shapes():
    tasks = {e[1] for e in aot.REGISTRY}
    assert tasks == {"lasso", "logreg", "multitask", "sgl"}
    by_name = {e[0]: e for e in aot.REGISTRY}
    # Leukemia shape of Figs. 3-4
    assert by_name["lasso_leukemia"][2:4] == (72, 7129)
    assert by_name["logreg_leukemia"][2:4] == (72, 7129)
    # climate groups of 7 (Fig. 6)
    assert by_name["sgl_climate"][5] == 7


def test_cli_writes_manifest(tmp_path):
    out = str(tmp_path)
    env = dict(os.environ)
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", out, "--only",
         "lasso_small,sgl_small"],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
    )
    with open(os.path.join(out, "manifest.json")) as f:
        man = json.load(f)
    names = {a["name"] for a in man["artifacts"]}
    assert names == {"lasso_small", "sgl_small"}
    for a in man["artifacts"]:
        assert os.path.exists(os.path.join(out, a["file"]))
        assert a["dtype"] == "f64"
        assert a["n_outputs"] in (6, 8)


def test_example_args_arity():
    assert len(model.example_args("lasso", 4, 6)) == 4
    assert len(model.example_args("multitask", 4, 6, q=3)) == 4
    assert len(model.example_args("sgl", 4, 6, group_size=2)) == 6
