"""L2 gap graphs: weak duality, feasibility of the rescaled dual point,
radius formula, cross-estimator consistency, convergence of the gap to 0
at an (ISTA-computed) optimum."""

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def _data(n, p, seed=0):
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.standard_normal((n, p)))
    y = jnp.asarray(rng.standard_normal(n))
    return X, y


def _ista_lasso(X, y, lam, iters=4000):
    """Plain ISTA oracle solver for the Lasso (test-only)."""
    L = float(jnp.linalg.norm(X, 2) ** 2)
    beta = jnp.zeros(X.shape[1])
    for _ in range(iters):
        grad = X.T @ (X @ beta - y)
        beta = ref.soft_threshold(beta - grad / L, lam / L)
    return beta


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(2, 24),
    p=st.integers(2, 60),
    frac=st.floats(0.05, 0.99),
    seed=st.integers(0, 2**31 - 1),
)
def test_lasso_weak_duality_and_feasibility(n, p, frac, seed):
    X, y = _data(n, p, seed)
    rng = np.random.default_rng(seed + 1)
    beta = jnp.asarray(rng.standard_normal(p)) * (rng.random(p) < 0.3)
    lam_max = float(jnp.max(jnp.abs(X.T @ y)))
    lam = frac * lam_max + 1e-12
    primal, dual, gap, radius, theta, cg = model.lasso_gap(X, y, beta, lam)
    assert float(dual) <= float(primal) + 1e-9
    assert float(gap) >= 0.0
    # theta in Delta_X: ||X^T theta||_inf <= 1
    assert float(jnp.max(jnp.abs(X.T @ theta))) <= 1.0 + 1e-9
    # radius matches Thm. 2 with gamma = 1
    np.testing.assert_allclose(float(radius), np.sqrt(2 * float(gap)) / lam, rtol=1e-12)
    # cg consistent
    np.testing.assert_allclose(np.asarray(cg), np.abs(np.asarray(X.T @ theta)), atol=1e-9)


def test_lasso_gap_vanishes_at_optimum():
    X, y = _data(12, 30, seed=5)
    lam = 0.4 * float(jnp.max(jnp.abs(X.T @ y)))
    beta = _ista_lasso(X, y, lam)
    _, _, gap, radius, theta, _ = model.lasso_gap(X, y, beta, lam)
    assert float(gap) < 1e-8
    assert float(radius) < 1e-3


@settings(max_examples=10, deadline=None)
@given(n=st.integers(2, 20), p=st.integers(2, 40), seed=st.integers(0, 2**31 - 1))
def test_logreg_weak_duality(n, p, seed):
    X, _ = _data(n, p, seed)
    rng = np.random.default_rng(seed + 7)
    y = jnp.asarray((rng.random(n) < 0.5).astype(float))
    beta = jnp.asarray(rng.standard_normal(p) * 0.1)
    lam_max = float(jnp.max(jnp.abs(X.T @ (y - 0.5))))
    lam = 0.5 * lam_max + 1e-12
    primal, dual, gap, radius, theta, cg = model.logreg_gap(X, y, beta, lam)
    assert float(dual) <= float(primal) + 1e-9
    assert float(jnp.max(jnp.abs(X.T @ theta))) <= 1.0 + 1e-9
    np.testing.assert_allclose(
        float(radius), np.sqrt(2 * float(gap) / 4.0) / lam, rtol=1e-12
    )


def test_logreg_primal_at_zero():
    """P(0) = n log 2 for any labels."""
    X, _ = _data(10, 15, seed=1)
    y = jnp.asarray((np.random.default_rng(2).random(10) < 0.5).astype(float))
    primal, *_ = model.logreg_gap(X, y, jnp.zeros(15), 1.0)
    np.testing.assert_allclose(float(primal), 10 * np.log(2.0), rtol=1e-12)


def test_multitask_q1_equals_lasso():
    X, y = _data(14, 25, seed=9)
    rng = np.random.default_rng(10)
    beta = jnp.asarray(rng.standard_normal(25)) * (rng.random(25) < 0.4)
    lam = 0.3 * float(jnp.max(jnp.abs(X.T @ y)))
    pl_, dl, gl, rl, tl, cl = model.lasso_gap(X, y, beta, lam)
    pm, dm, gm, rm, tm, cm = model.multitask_gap(X, y[:, None], beta[:, None], lam)
    np.testing.assert_allclose(float(pl_), float(pm), rtol=1e-12)
    np.testing.assert_allclose(float(dl), float(dm), rtol=1e-10, atol=1e-12)
    np.testing.assert_allclose(np.asarray(cl), np.asarray(cm), atol=1e-10)


@settings(max_examples=8, deadline=None)
@given(
    n=st.integers(4, 16),
    p=st.integers(2, 20),
    q=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_multitask_feasibility(n, p, q, seed):
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.standard_normal((n, p)))
    Y = jnp.asarray(rng.standard_normal((n, q)))
    B = jnp.asarray(rng.standard_normal((p, q)) * (rng.random((p, 1)) < 0.3))
    lam = 0.4 * float(jnp.max(jnp.linalg.norm(X.T @ Y, axis=1))) + 1e-12
    primal, dual, gap, radius, Theta, cg = model.multitask_gap(X, Y, B, lam)
    assert float(dual) <= float(primal) + 1e-9
    assert float(jnp.max(jnp.linalg.norm(X.T @ Theta, axis=1))) <= 1.0 + 1e-9


def test_sgl_tau1_equals_lasso():
    X, y = _data(12, 24, seed=3)
    rng = np.random.default_rng(4)
    beta = jnp.asarray(rng.standard_normal(24)) * (rng.random(24) < 0.4)
    w = jnp.ones(6)
    lam = 0.3 * float(jnp.max(jnp.abs(X.T @ y)))
    pl_, dl, gl, rl, tl, cl = model.lasso_gap(X, y, beta, lam)
    ps, ds, gs_, rs, ts, cf, sg, mg = model.sgl_gap(X, y, beta, lam, 1.0, w, 4)
    np.testing.assert_allclose(float(pl_), float(ps), rtol=1e-12)
    np.testing.assert_allclose(float(gl), float(gs_), rtol=1e-8, atol=1e-10)
    np.testing.assert_allclose(np.asarray(cl), np.asarray(cf), atol=1e-9)


def test_sgl_tau0_equals_group_lasso_dual_norm():
    """tau = 0: the SGL statistic sg equals the group-lasso ||X_g^T theta||_2."""
    X, y = _data(12, 24, seed=13)
    w = jnp.ones(6)
    beta = jnp.zeros(24)
    corr = (X.T @ y).reshape(6, 4)
    lam = 0.5 * float(jnp.max(jnp.linalg.norm(corr, axis=1)))
    ps, ds, gs_, rs, ts, cf, sg, mg = model.sgl_gap(X, y, beta, lam, 0.0, w, 4)
    theta = np.asarray(ts)
    want = np.linalg.norm((np.asarray(X).T @ theta).reshape(6, 4), axis=1)
    np.testing.assert_allclose(np.asarray(sg), want, atol=1e-9)


@settings(max_examples=10, deadline=None)
@given(
    G=st.integers(1, 6),
    gs=st.integers(1, 6),
    tau=st.floats(0.05, 0.95),
    seed=st.integers(0, 2**31 - 1),
)
def test_sgl_feasibility(G, gs, tau, seed):
    n, p = 10, G * gs
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.standard_normal((n, p)))
    y = jnp.asarray(rng.standard_normal(n))
    w = jnp.asarray(rng.uniform(0.5, 1.5, G))
    beta = jnp.asarray(rng.standard_normal(p) * (rng.random(p) < 0.3))
    lam_max = float(ref.sgl_dual_norm((X.T @ y).reshape(G, gs), tau, w))
    lam = 0.6 * lam_max + 1e-12
    ps, ds, g, r, theta, cf, sg, mg = model.sgl_gap(X, y, beta, lam, tau, w, gs)
    assert float(ds) <= float(ps) + 1e-9
    dn = float(ref.sgl_dual_norm((X.T @ theta).reshape(G, gs), tau, w))
    assert dn <= 1.0 + 1e-9
    assert float(g) >= 0.0
