"""The epsilon-norm (Eq. 25) and the SGL dual norm (Prop. 7)."""

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from hypothesis import given, settings, strategies as st

from compile.kernels import ref


@settings(max_examples=40, deadline=None)
@given(
    d=st.integers(1, 20),
    eps=st.floats(1e-6, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_defining_equation(d, eps, seed):
    """nu = ||x||_eps satisfies sum (|x_i| - (1-eps) nu)_+^2 = (eps nu)^2."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(d))
    nu = float(ref.epsilon_norm(x, eps))
    lhs = float(jnp.sum(jnp.maximum(jnp.abs(x) - (1 - eps) * nu, 0.0) ** 2))
    rhs = (eps * nu) ** 2
    assert abs(lhs - rhs) <= 1e-9 * max(1.0, rhs)


@settings(max_examples=30, deadline=None)
@given(d=st.integers(1, 20), seed=st.integers(0, 2**31 - 1))
def test_limits(d, seed):
    """eps = 0 -> sup norm, eps = 1 -> l2 norm (conventions below Eq. 25)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(d))
    np.testing.assert_allclose(ref.epsilon_norm(x, 0.0), jnp.max(jnp.abs(x)), rtol=1e-12)
    np.testing.assert_allclose(ref.epsilon_norm(x, 1.0), jnp.linalg.norm(x), rtol=1e-10)


@settings(max_examples=30, deadline=None)
@given(
    d=st.integers(1, 16),
    eps=st.floats(1e-4, 1.0),
    scale=st.floats(1e-3, 1e3),
    seed=st.integers(0, 2**31 - 1),
)
def test_homogeneity_and_bounds(d, eps, scale, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(d))
    nu = float(ref.epsilon_norm(x, eps))
    nus = float(ref.epsilon_norm(scale * x, eps))
    assert abs(nus - scale * nu) <= 1e-8 * max(1.0, scale * nu)
    # sandwich: ||x||_inf <= ||x||_eps... actually ||x||_eps >= ||x||_2 >= ||x||_inf? No:
    # monotone: ||x||_eps decreases as eps grows from 0 ... it interpolates between
    # ||x||_inf (eps=0) and ||x||_2 (eps=1); both bounds hold:
    lo = min(float(jnp.max(jnp.abs(x))), float(jnp.linalg.norm(x)))
    hi = max(float(jnp.max(jnp.abs(x))), float(jnp.linalg.norm(x)))
    assert lo - 1e-9 <= nu <= hi + 1e-9


@settings(max_examples=25, deadline=None)
@given(
    d=st.integers(1, 12),
    eps=st.floats(1e-4, 1.0 - 1e-9),
    seed=st.integers(0, 2**31 - 1),
)
def test_dual_norm_identity_eq26(d, eps, seed):
    """Holder: <z, xi> <= ||z||_eps * (eps ||xi||_2 + (1-eps) ||xi||_1)  (Eq. 26)."""
    rng = np.random.default_rng(seed)
    z = jnp.asarray(rng.standard_normal(d))
    xi = jnp.asarray(rng.standard_normal(d))
    lhs = float(jnp.dot(z, xi))
    dual = eps * float(jnp.linalg.norm(xi)) + (1 - eps) * float(jnp.sum(jnp.abs(xi)))
    nu = float(ref.epsilon_norm(z, eps))
    assert lhs <= nu * dual + 1e-9 * max(1.0, abs(lhs))


@settings(max_examples=20, deadline=None)
@given(
    G=st.integers(1, 8),
    gs=st.integers(1, 8),
    tau=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_sgl_primal_identity_prop7(G, gs, tau, seed):
    """Prop. 7: Omega = sum_g (tau + (1-tau) w_g) ||beta_g||^D_{eps_g} with
    the dual epsilon-norm of Eq. (26)."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.uniform(0.5, 2.0, G))
    if tau == 0.0:
        w = jnp.maximum(w, 0.5)  # Omega must remain a norm
    B = jnp.asarray(rng.standard_normal((G, gs)))
    eps = ref.sgl_epsilons(tau, w)
    dual_eps = eps * jnp.linalg.norm(B, axis=1) + (1 - eps) * jnp.sum(jnp.abs(B), axis=1)
    lhs = float(jnp.sum((tau + (1 - tau) * w) * dual_eps))
    rhs = float(ref.sgl_penalty(B, tau, w))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-12)


def test_sgl_dual_norm_reduces_to_linf_and_group():
    rng = np.random.default_rng(3)
    G, gs = 5, 4
    xi = jnp.asarray(rng.standard_normal((G, gs)))
    w = jnp.ones(G)
    # tau = 1 -> Lasso: Omega^D = ||.||_inf
    np.testing.assert_allclose(
        ref.sgl_dual_norm(xi, 1.0, w), jnp.max(jnp.abs(xi)), rtol=1e-10
    )
    # tau = 0 -> Group Lasso: Omega^D = max_g ||xi_g||_2 / w_g
    np.testing.assert_allclose(
        ref.sgl_dual_norm(xi, 0.0, w),
        jnp.max(jnp.linalg.norm(xi, axis=1) / w),
        rtol=1e-10,
    )
