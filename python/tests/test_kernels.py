"""L1 Pallas kernels vs the pure-jnp oracle (hypothesis sweeps shapes/dtypes)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from hypothesis import given, settings, strategies as st

from compile.kernels import ref, screen

F64 = jnp.float64
F32 = jnp.float32


def _rng(seed):
    return np.random.default_rng(seed)


def _tol(dtype):
    return dict(rtol=1e-10, atol=1e-10) if dtype == F64 else dict(rtol=2e-4, atol=2e-4)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 48),
    p=st.integers(1, 300),
    bp=st.sampled_from([1, 3, 16, 64, 256]),
    dtype=st.sampled_from([F32, F64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_xtv_matches_ref(n, p, bp, dtype, seed):
    rng = _rng(seed)
    X = jnp.asarray(rng.standard_normal((n, p)), dtype=dtype)
    v = jnp.asarray(rng.standard_normal(n), dtype=dtype)
    got = screen.xtv(X, v, block_p=bp)
    want = ref.xtv_ref(X, v)
    np.testing.assert_allclose(got, want, **_tol(dtype))


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 32),
    p=st.integers(1, 200),
    q=st.integers(1, 12),
    bp=st.sampled_from([1, 8, 64]),
    dtype=st.sampled_from([F32, F64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_xtm_matches_ref(n, p, q, bp, dtype, seed):
    rng = _rng(seed)
    X = jnp.asarray(rng.standard_normal((n, p)), dtype=dtype)
    V = jnp.asarray(rng.standard_normal((n, q)), dtype=dtype)
    got = screen.xtm(X, V, block_p=bp)
    want = ref.xtm_ref(X, V)
    np.testing.assert_allclose(got, want, **_tol(dtype))


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 32),
    p=st.integers(1, 200),
    bp=st.sampled_from([1, 8, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_l1_scores_matches_ref(n, p, bp, seed):
    rng = _rng(seed)
    X = jnp.asarray(rng.standard_normal((n, p)), dtype=F64)
    v = jnp.asarray(rng.standard_normal(n), dtype=F64)
    nrm = jnp.sqrt(jnp.sum(X * X, axis=0))
    inv_alpha = jnp.float64(rng.uniform(0.1, 2.0))
    radius = jnp.float64(rng.uniform(0.0, 1.0))
    got = screen.l1_scores(X, v, nrm, inv_alpha, radius, block_p=bp)
    want = ref.l1_scores_ref(X, v, nrm, inv_alpha, radius)
    np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-10)


def test_xtv_prime_p_leukemia_shape():
    """p = 7129 is prime: exercises the zero-padding path on the real shape."""
    rng = _rng(0)
    X = jnp.asarray(rng.standard_normal((8, 7129)), dtype=F64)
    v = jnp.asarray(rng.standard_normal(8), dtype=F64)
    got = screen.xtv(X, v)
    np.testing.assert_allclose(got, ref.xtv_ref(X, v), rtol=1e-10, atol=1e-10)


@pytest.mark.parametrize("p,bp", [(0o1, 1), (5, 5), (256, 256), (257, 256)])
def test_xtv_block_boundaries(p, bp):
    rng = _rng(p * 1000 + bp)
    X = jnp.asarray(rng.standard_normal((4, p)), dtype=F64)
    v = jnp.asarray(rng.standard_normal(4), dtype=F64)
    np.testing.assert_allclose(
        screen.xtv(X, v, block_p=bp), ref.xtv_ref(X, v), rtol=1e-10, atol=1e-10
    )
