"""AOT compile path: lower every (task, shape) gap graph to HLO *text*.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's bundled
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out ../artifacts

Emits one ``<name>.hlo.txt`` per registry entry plus ``manifest.json``
describing shapes / dtypes / output arity so the Rust runtime can bind
buffers without re-deriving anything from Python.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc

from . import model


# (name, task, n, p, q, group_size).  Names are referenced from the Rust
# artifact registry (rust/src/runtime/artifact.rs) and from examples/benches.
REGISTRY = [
    # small shapes used by unit / integration tests on both sides
    ("lasso_small", "lasso", 16, 40, 1, 1),
    ("logreg_small", "logreg", 16, 40, 1, 1),
    ("multitask_small", "multitask", 16, 40, 4, 1),
    ("sgl_small", "sgl", 16, 40, 1, 4),
    # quickstart-scale
    ("lasso_quickstart", "lasso", 100, 500, 1, 1),
    # Fig. 3 / Fig. 4 — Leukemia-shaped (n = 72, p = 7129)
    ("lasso_leukemia", "lasso", 72, 7129, 1, 1),
    ("logreg_leukemia", "logreg", 72, 7129, 1, 1),
    # Fig. 5 — MEG/EEG-shaped (bench default n = 360, p = 5000, q = 20)
    ("multitask_meg", "multitask", 360, 5000, 20, 1),
    # Fig. 6 — NCEP/NCAR-shaped (bench default n = 200, p = 7000, gs = 7)
    ("sgl_climate", "sgl", 200, 7000, 1, 7),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(task: str, n: int, p: int, q: int, gs: int) -> str:
    fn = model.gap_fn(task, gs)
    args = model.example_args(task, n, p, q, gs)
    return to_hlo_text(jax.jit(fn).lower(*args))


def n_outputs(task: str) -> int:
    return 8 if task == "sgl" else 6


def input_names(task: str) -> list[str]:
    if task in ("lasso", "logreg"):
        return ["X", "y", "beta", "lam"]
    if task == "multitask":
        return ["X", "Y", "B", "lam"]
    return ["X", "y", "beta", "lam", "tau", "w"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated entry names")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    only = set(args.only.split(",")) if args.only else None

    entries = []
    for name, task, n, p, q, gs in REGISTRY:
        if only is not None and name not in only:
            continue
        text = lower_entry(task, n, p, q, gs)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out, fname)
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        entries.append(
            {
                "name": name,
                "task": task,
                "file": fname,
                "n": n,
                "p": p,
                "q": q,
                "group_size": gs,
                "dtype": "f64",
                "inputs": input_names(task),
                "n_outputs": n_outputs(task),
                "sha256_16": digest,
            }
        )
        print(f"wrote {path} ({len(text)} chars, sha {digest})", file=sys.stderr)

    manifest = {"version": 1, "artifacts": entries}
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"manifest: {len(entries)} artifacts -> {args.out}/manifest.json", file=sys.stderr)


if __name__ == "__main__":
    main()
