"""Layer-2 JAX graphs: one fused duality-gap / screening pass per estimator.

Each ``*_gap`` function implements, for one estimator of Table 1, the whole
computation a Gap Safe screening step needs (Alg. 2, lines 3-4):

  1. generalized residual      rho   = -G(X beta)            (Remark 2)
  2. dual rescaling            theta = rho / max(lambda, Omega^D(X^T rho))
                                                             (Eq. 9 / 18)
  3. primal objective          P_lambda(beta)                (Eq. 1)
  4. dual objective            D_lambda(theta)               (Eq. 4)
  5. duality gap + Gap Safe radius  r = sqrt(2 Gap / (gamma lambda^2))
                                                             (Thm. 2)
  6. per-group screening statistics Omega_g^D(X_g^T theta)   (Eq. 8 / Prop. 8)

The O(np) correlation X^T rho goes through the Layer-1 Pallas kernel
(kernels.screen.xtv / xtm) so the whole pass is a single lowered HLO module;
everything downstream of the correlation is O(p). ``aot.py`` lowers these
functions for a registry of named shapes to ``artifacts/*.hlo.txt`` which
the Rust runtime loads and executes via PJRT (Python is never on the
request path).

All graphs are pure f64 (the Rust coordinator screens with exact tests; a
safe rule evaluated in f32 could discard a feature whose score is within
f32 rounding of 1).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from .kernels import ref
from .kernels import screen

# ---------------------------------------------------------------------------
# Lasso  (Sec. 4.1):  f_i(z) = (y_i - z)^2 / 2,  Omega = ||.||_1,  gamma = 1.
# ---------------------------------------------------------------------------


def lasso_gap(X, y, beta, lam):
    """Gap pass for the Lasso.

    Returns (primal, dual, gap, radius, theta, cg) where
    ``cg[j] = |X_j^T theta|`` is the screening statistic of Eq. (8): the
    coordinator screens feature j iff ``cg[j] + radius * ||X_j||_2 < 1``.
    """
    rho = y - X @ beta  # -G(X beta) for the quadratic fit
    corr = screen.xtv(X, rho)
    dnorm = jnp.max(jnp.abs(corr))
    alpha = jnp.maximum(lam, dnorm)
    theta = rho / alpha
    primal = 0.5 * jnp.sum(rho * rho) + lam * jnp.sum(jnp.abs(beta))
    # D(theta) = (||y||^2 - ||y - lam theta||^2) / 2
    dual = 0.5 * (jnp.sum(y * y) - jnp.sum((y - lam * theta) ** 2))
    gap = jnp.maximum(primal - dual, 0.0)
    radius = jnp.sqrt(2.0 * gap) / lam  # gamma = 1
    cg = jnp.abs(corr) / alpha
    return primal, dual, gap, radius, theta, cg


# ---------------------------------------------------------------------------
# l1 binary logistic regression (Sec. 4.4):
#   f_i(z) = -y_i z + log(1 + e^z),  f_i^*(u) = Nh(u + y_i),  gamma = 4.
# ---------------------------------------------------------------------------


def logreg_gap(X, y, beta, lam):
    """Gap pass for l1-regularized binary logistic regression (labels in {0,1})."""
    z = X @ beta
    sig = jax.nn.sigmoid(z)
    rho = y - sig  # -G(X beta) = -(sigma(z) - y)
    corr = screen.xtv(X, rho)
    dnorm = jnp.max(jnp.abs(corr))
    alpha = jnp.maximum(lam, dnorm)
    theta = rho / alpha
    # primal: softplus(z) - y z, numerically stable
    primal = jnp.sum(jax.nn.softplus(z) - y * z) + lam * jnp.sum(jnp.abs(beta))
    # dual: -sum Nh(-lam theta_i + y_i)
    dual = -jnp.sum(ref.negative_entropy(y - lam * theta))
    gap = jnp.maximum(primal - dual, 0.0)
    radius = jnp.sqrt(2.0 * gap / 4.0) / lam  # gamma = 4
    cg = jnp.abs(corr) / alpha
    return primal, dual, gap, radius, theta, cg


# ---------------------------------------------------------------------------
# l1/l2 multi-task regression (Sec. 4.5):
#   row-groups of B in R^{p x q},  Omega = sum_j ||B_j||_2,  gamma = 1.
# ---------------------------------------------------------------------------


def multitask_gap(X, Y, B, lam):
    """Gap pass for the multi-task Lasso.

    Returns (primal, dual, gap, radius, Theta, cg) with
    ``cg[j] = ||X_j^T Theta||_2`` (the l_inf/l_2 dual norm statistic).
    """
    R = Y - X @ B  # (n, q) residual
    C = screen.xtm(X, R)  # (p, q) correlations
    row_norms = jnp.sqrt(jnp.sum(C * C, axis=1))
    dnorm = jnp.max(row_norms)
    alpha = jnp.maximum(lam, dnorm)
    Theta = R / alpha
    pen = jnp.sum(jnp.sqrt(jnp.sum(B * B, axis=1)))
    primal = 0.5 * jnp.sum(R * R) + lam * pen
    dual = 0.5 * (jnp.sum(Y * Y) - jnp.sum((Y - lam * Theta) ** 2))
    gap = jnp.maximum(primal - dual, 0.0)
    radius = jnp.sqrt(2.0 * gap) / lam
    cg = row_norms / alpha
    return primal, dual, gap, radius, Theta, cg


# ---------------------------------------------------------------------------
# Sparse-Group Lasso (Sec. 4.3): Omega_{tau,w}, two-level screening (Prop. 8).
# Uniform group size gs (the climate workload has gs = 7); the Rust native
# path additionally supports ragged groups.
# ---------------------------------------------------------------------------


def sgl_gap(X, y, beta, lam, tau, w, group_size: int):
    """Gap pass for the Sparse-Group Lasso.

    Returns (primal, dual, gap, radius, theta, cf, sg, mg):
      cf[j] = |X_j^T theta|                      — feature-level statistic,
      sg[g] = ||S_tau(X_g^T theta)||_2           — group-level statistic,
      mg[g] = ||X_g^T theta||_inf                — for the T_g bound branch.
    The coordinator applies Prop. 8 with its precomputed column/group norms.
    """
    p = X.shape[1]
    G = p // group_size
    rho = y - X @ beta
    corr = screen.xtv(X, rho)  # (p,)
    corr_g = corr.reshape(G, group_size)
    dnorm = ref.sgl_dual_norm(corr_g, tau, w)
    alpha = jnp.maximum(lam, dnorm)
    theta = rho / alpha
    beta_g = beta.reshape(G, group_size)
    primal = 0.5 * jnp.sum(rho * rho) + lam * ref.sgl_penalty(beta_g, tau, w)
    dual = 0.5 * (jnp.sum(y * y) - jnp.sum((y - lam * theta) ** 2))
    gap = jnp.maximum(primal - dual, 0.0)
    radius = jnp.sqrt(2.0 * gap) / lam
    ctheta = corr_g / alpha
    st = ref.soft_threshold(ctheta, tau)
    sg = jnp.sqrt(jnp.sum(st * st, axis=1))
    mg = jnp.max(jnp.abs(ctheta), axis=1)
    cf = jnp.abs(corr) / alpha
    return primal, dual, gap, radius, theta, cf, sg, mg


# ---------------------------------------------------------------------------
# Registry used by aot.py — names, example-arg builders, metadata.
# ---------------------------------------------------------------------------


def example_args(task: str, n: int, p: int, q: int = 1, group_size: int = 1):
    """Build ShapeDtypeStructs for lowering one (task, shape) artifact."""
    f64 = jnp.float64
    Xs = jax.ShapeDtypeStruct((n, p), f64)
    if task == "lasso":
        return (Xs, jax.ShapeDtypeStruct((n,), f64), jax.ShapeDtypeStruct((p,), f64), jax.ShapeDtypeStruct((), f64))
    if task == "logreg":
        return (Xs, jax.ShapeDtypeStruct((n,), f64), jax.ShapeDtypeStruct((p,), f64), jax.ShapeDtypeStruct((), f64))
    if task == "multitask":
        return (
            Xs,
            jax.ShapeDtypeStruct((n, q), f64),
            jax.ShapeDtypeStruct((p, q), f64),
            jax.ShapeDtypeStruct((), f64),
        )
    if task == "sgl":
        G = p // group_size
        return (
            Xs,
            jax.ShapeDtypeStruct((n,), f64),
            jax.ShapeDtypeStruct((p,), f64),
            jax.ShapeDtypeStruct((), f64),
            jax.ShapeDtypeStruct((), f64),
            jax.ShapeDtypeStruct((G,), f64),
        )
    raise ValueError(f"unknown task {task!r}")


def gap_fn(task: str, group_size: int = 1):
    """Return the jittable gap function for ``task``."""
    if task == "lasso":
        return lasso_gap
    if task == "logreg":
        return logreg_gap
    if task == "multitask":
        return multitask_gap
    if task == "sgl":
        return lambda X, y, b, lam, tau, w: sgl_gap(X, y, b, lam, tau, w, group_size)
    raise ValueError(f"unknown task {task!r}")
