"""Pure-jnp oracle implementations used to validate the Pallas kernels and
the L2 gap graphs (pytest / hypothesis compare against these).

Also hosts the shared numerical building blocks of the paper:

* soft-thresholding  S_tau (Sec. 2.1),
* the epsilon-norm of Eq. (25) (Burdakov), computed by a fixed-iteration
  bisection on the strictly decreasing map
  ``phi(nu) = ||S_{(1-eps) nu}(x)||_2 - eps * nu``  — JAX-friendly
  (static iteration count) and correct for every eps in [0, 1],
* the Sparse-Group Lasso dual norm of Prop. 7.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

BISECT_ITERS = 100  # 2^-100 relative bracket: beyond f64 resolution.


def xtv_ref(X: jax.Array, v: jax.Array) -> jax.Array:
    """Oracle for kernels.screen.xtv: plain ``X.T @ v``."""
    return X.T @ v


def xtm_ref(X: jax.Array, V: jax.Array) -> jax.Array:
    """Oracle for kernels.screen.xtm: plain ``X.T @ V``."""
    return X.T @ V


def l1_scores_ref(X, v, col_norms, inv_alpha, radius):
    """Oracle for kernels.screen.l1_scores."""
    return jnp.abs(X.T @ v) * inv_alpha + radius * col_norms


def soft_threshold(x: jax.Array, tau) -> jax.Array:
    """Elementwise soft-thresholding  S_tau(x) = sign(x) (|x| - tau)_+."""
    return jnp.sign(x) * jnp.maximum(jnp.abs(x) - tau, 0.0)


def epsilon_norm(x: jax.Array, eps, axis: int = -1) -> jax.Array:
    """Epsilon-norm ||x||_eps of Eq. (25): unique nu >= 0 solving

        sum_i (|x_i| - (1 - eps) nu)_+^2 = (eps nu)^2 ,

    with the conventions ||x||_{eps=0} = ||x||_inf and ||x||_{eps=1} = ||x||_2.

    Vectorised over leading axes; ``eps`` broadcasts against the reduced
    shape.  Uses bisection on phi(nu) = ||S_{(1-eps)nu}(x)||_2 - eps*nu,
    which is strictly decreasing (phi' <= -eps), bracketed by
    [||x||_inf * (1-eps), ||x||_2 / max(eps, tiny)].
    """
    ax = jnp.abs(x)
    linf = jnp.max(ax, axis=axis)
    l2 = jnp.sqrt(jnp.sum(ax * ax, axis=axis))
    eps = jnp.asarray(eps, dtype=x.dtype)
    eps_c = jnp.clip(eps, 1e-12, 1.0)
    eps_e = jnp.expand_dims(jnp.broadcast_to(eps_c, linf.shape), axis)

    def phi(nu):
        nu_e = jnp.expand_dims(nu, axis)
        s = jnp.maximum(ax - (1.0 - eps_e) * nu_e, 0.0)
        return jnp.sqrt(jnp.sum(s * s, axis=axis)) - eps_c * nu

    lo = jnp.zeros_like(l2)
    hi = l2 / eps_c + 1e-30

    def body(_, state):
        lo, hi = state
        mid = 0.5 * (lo + hi)
        pos = phi(mid) > 0.0
        return jnp.where(pos, mid, lo), jnp.where(pos, hi, mid)

    lo, hi = jax.lax.fori_loop(0, BISECT_ITERS, body, (lo, hi))
    nu = 0.5 * (lo + hi)
    # eps == 0 limit: the infimum is ||x||_inf.
    return jnp.where(eps <= 1e-12, linf, nu)


def sgl_epsilons(tau, w: jax.Array) -> jax.Array:
    """Per-group eps_g = (1 - tau) w_g / (tau + (1 - tau) w_g)  (Prop. 7)."""
    return (1.0 - tau) * w / (tau + (1.0 - tau) * w)


def sgl_dual_norm(xi_groups: jax.Array, tau, w: jax.Array) -> jax.Array:
    """Sparse-Group Lasso dual norm (Prop. 7) for uniformly sized groups.

    Args:
      xi_groups: shape (G, gs) — xi reshaped to one row per group.
      tau: ell_1 trade-off in [0, 1].
      w: group weights, shape (G,).

    Returns:
      Omega^D(xi) = max_g ||xi_g||_{eps_g} / (tau + (1 - tau) w_g).
    """
    eps = sgl_epsilons(tau, w)
    nrm = epsilon_norm(xi_groups, eps, axis=-1)
    return jnp.max(nrm / (tau + (1.0 - tau) * w))


def sgl_penalty(beta_groups: jax.Array, tau, w: jax.Array) -> jax.Array:
    """Omega_{tau,w}(beta) = tau ||beta||_1 + (1-tau) sum_g w_g ||beta_g||_2."""
    l1 = jnp.sum(jnp.abs(beta_groups))
    l2 = jnp.sum(w * jnp.sqrt(jnp.sum(beta_groups * beta_groups, axis=-1)))
    return tau * l1 + (1.0 - tau) * l2


def negative_entropy(x: jax.Array) -> jax.Array:
    """Binary negative entropy Nh (Eq. 28), elementwise, with 0 log 0 = 0.

    Returns +inf outside [0, 1] in exact arithmetic; here inputs are always
    feasible by construction (Remark 14), so we clamp for numerical safety.
    """
    xc = jnp.clip(x, 1e-300, 1.0)
    xm = jnp.clip(1.0 - x, 1e-300, 1.0)
    return xc * jnp.log(xc) + xm * jnp.log(xm)
