"""Layer-1 Pallas kernels for the Gap Safe screening hot spot.

The O(np) cost of one screening / duality-gap pass is the correlation of
every feature (column of X) with the current residual / dual point:
``c = X^T v`` (Lasso, logistic) or ``C = X^T V`` (multi-task).  These are
expressed as column-block-tiled Pallas kernels: the grid walks tiles of
``BP`` columns, each tile performs a ``(BP, n) x (n,)`` contraction.

On a real TPU each tile is sized to VMEM (8 * n * BP bytes for f64) and the
contraction maps to the MXU; on this testbed the kernels run under
``interpret=True`` (the CPU PJRT plugin cannot execute Mosaic custom-calls),
so we optimise the *structure* (tiling, single pass over X, fusion with the
downstream score computation) rather than interpret-mode wallclock.

Columns are zero-padded up to a multiple of the block size inside the jitted
graph (padded columns contribute exact zeros and are sliced off), so any
``p`` — including the prime p = 7129 of the Leukemia workload — is supported.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default number of columns per tile (perf pass: 256 -> 1024, a 3.1x
# artifact-execution win; see EXPERIMENTS.md §Perf — fewer grid steps in the
# interpret-lowered while-loop, and an f64 tile of n=814 x 1024 is 6.7 MB,
# still inside a 16 MB VMEM budget with double buffering on a real TPU.
# BP = 2048 bought a further 23% of interpret wallclock but its 13.3 MB
# tile leaves no room to double-buffer at n = 814 — rejected, see §Perf).
DEFAULT_BLOCK_P = 1024


def _xtv_kernel(x_ref, v_ref, o_ref):
    """One tile: o = X_tile^T v  with X_tile in VMEM, shape (n, BP)."""
    o_ref[...] = x_ref[...].T @ v_ref[...]


def _xtm_kernel(x_ref, v_ref, o_ref):
    """One tile: O = X_tile^T V  for the multi-task case, V of shape (n, q)."""
    o_ref[...] = x_ref[...].T @ v_ref[...]


def _pad_cols(X: jax.Array, bp: int) -> tuple[jax.Array, int]:
    n, p = X.shape
    pp = ((p + bp - 1) // bp) * bp
    if pp != p:
        X = jnp.pad(X, ((0, 0), (0, pp - p)))
    return X, pp


@functools.partial(jax.jit, static_argnames=("block_p",))
def xtv(X: jax.Array, v: jax.Array, block_p: int = DEFAULT_BLOCK_P) -> jax.Array:
    """Compute ``X.T @ v`` with a column-tiled Pallas kernel.

    Args:
      X: design matrix, shape (n, p).
      v: vector, shape (n,).
      block_p: columns per tile (static).

    Returns:
      Vector of shape (p,), equal to ``X.T @ v``.
    """
    n, p = X.shape
    bp = min(block_p, max(p, 1))
    Xp, pp = _pad_cols(X, bp)
    out = pl.pallas_call(
        _xtv_kernel,
        grid=(pp // bp,),
        in_specs=[
            pl.BlockSpec((n, bp), lambda j: (0, j)),
            pl.BlockSpec((n,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((bp,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((pp,), X.dtype),
        interpret=True,
    )(Xp, v)
    return out[:p]


@functools.partial(jax.jit, static_argnames=("block_p",))
def xtm(X: jax.Array, V: jax.Array, block_p: int = DEFAULT_BLOCK_P) -> jax.Array:
    """Compute ``X.T @ V`` (multi-task correlation) with a column-tiled kernel.

    Args:
      X: design matrix, shape (n, p).
      V: residual matrix, shape (n, q).

    Returns:
      Matrix of shape (p, q).
    """
    n, p = X.shape
    q = V.shape[1]
    bp = min(block_p, max(p, 1))
    Xp, pp = _pad_cols(X, bp)
    out = pl.pallas_call(
        _xtm_kernel,
        grid=(pp // bp,),
        in_specs=[
            pl.BlockSpec((n, bp), lambda j: (0, j)),
            pl.BlockSpec((n, q), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bp, q), lambda j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((pp, q), X.dtype),
        interpret=True,
    )(Xp, V)
    return out[:p]


def _score_kernel(x_ref, v_ref, nrm_ref, scal_ref, o_ref):
    """Fused screening-score tile: o = |X^T v| * inv_alpha + radius * ||X_j||.

    Fuses the correlation, the dual rescaling and the sphere-test bound of
    Eq. (8) so X is read exactly once per screening pass.  ``scal_ref``
    carries the two runtime scalars [1/alpha, radius].
    """
    c = x_ref[...].T @ v_ref[...]
    o_ref[...] = jnp.abs(c) * scal_ref[0] + scal_ref[1] * nrm_ref[...]


@functools.partial(jax.jit, static_argnames=("block_p",))
def l1_scores(
    X: jax.Array,
    v: jax.Array,
    col_norms: jax.Array,
    inv_alpha: jax.Array,
    radius: jax.Array,
    block_p: int = DEFAULT_BLOCK_P,
) -> jax.Array:
    """Fused ℓ1 sphere-test scores ``|X_j^T v|/alpha + r * ||X_j||_2``.

    A feature j is Gap-Safe screened iff the returned score is < 1.
    """
    n, p = X.shape
    bp = min(block_p, max(p, 1))
    Xp, pp = _pad_cols(X, bp)
    nrm = jnp.pad(col_norms, (0, pp - p)) if pp != p else col_norms
    scal = jnp.stack([inv_alpha, radius]).astype(X.dtype)
    out = pl.pallas_call(
        _score_kernel,
        grid=(pp // bp,),
        in_specs=[
            pl.BlockSpec((n, bp), lambda j: (0, j)),
            pl.BlockSpec((n,), lambda j: (0,)),
            pl.BlockSpec((bp,), lambda j: (j,)),
            pl.BlockSpec((2,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((bp,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((pp,), X.dtype),
        interpret=True,
    )(Xp, v, nrm, scal)
    return out[:p]
